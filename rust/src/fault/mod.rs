//! Fault modeling and failover machinery: seeded [`FaultPlan`]s, plan
//! diffing ([`PlanDiff`]), and the degradation arithmetic behind
//! [`crate::plan::Planner::replan`].
//!
//! The paper's layer-wise pipeline keeps >90% of the DSPs busy precisely
//! because every resource is committed — which means a board loss, a DDR
//! brownout, or a failed partial reconfiguration takes out whole tenants
//! unless the system can re-plan and degrade gracefully. This module is
//! the typed fault model the rest of the crate consumes:
//!
//! - [`FaultPlan`] — a versioned, JSON-serializable, **seeded** fault
//!   scenario: board loss at time *t* with a surviving capacity fraction,
//!   DDR bandwidth degradation, reconfiguration overrun/failure, and a
//!   transient backend error burst for the serving path. Every stochastic
//!   choice derives from [`FaultPlan::seed`] through the crate's
//!   deterministic xorshift PRNG, so the same fault file produces
//!   byte-identical reports on every run (CI diffs them).
//! - [`crate::sim::Simulator::simulate_faulted`] — executes a deployment
//!   plan *under* a fault plan and reports per-tenant fps/sojourn with the
//!   faults injected into the DES engines.
//! - [`PlanDiff`] — the typed delta between two [`DeploymentPlan`]s:
//!   per-tenant θ/α/schedule changes plus the minimal drain-overlapped
//!   reconfiguration sequence to execute the transition (reusing the PR-4
//!   drain-credit machinery of [`crate::shard`]). `apply(a, diff(a, b))`
//!   reconstructs `b` byte-identically (property-pinned), and the diff's
//!   reconfiguration cost is bounded by the full-swap cost in both
//!   directions.
//!
//! # Fault semantics (what is injected where)
//!
//! | Fault | Simulation ([`crate::sim::Simulator::simulate_faulted`]) | Re-planning ([`crate::plan::Planner::replan`]) |
//! |---|---|---|
//! | `board_loss.at_s` | The deployed fabric serves until *t*, then stops: per-tenant effective fps is scaled by the fraction of the simulated horizon served. | Ignored (re-planning is about *capacity*). |
//! | `board_loss.survive_frac` | Ignored — a committed pipeline cannot partially survive; until failover the deployed bitstream is all-or-nothing. | Scales the board's DSP/LUT/FF/BRAM budgets; tenants are re-admitted against the surviving fabric. |
//! | `ddr_factor` | Scales the DDR port rate the running pipelines stream against (brownout: the fabric runs, the port slows). | Scales the surviving board's port rate. |
//! | `reconfig` | Rewrites each schedule slice's swap cost: `overrun_factor` multiplies it, and a seeded per-slice coin with `failure_prob` doubles it (a failed swap is retried — streamed again). Overruns stretch the period; frames are never dropped. | Inherited by the re-planned schedule through the board it is planned on. |
//! | `backend_errors` | Not a DES fault — consumed by the serving path (the coordinator's retry/backoff hardening is tested against exactly this burst shape). | Ignored. |

use crate::board::Board;
use crate::plan::{DeploymentPlan, PlanTenant};
use crate::sim::ScheduleSlice;
use crate::util::json::{self, num, obj, Value};
use crate::util::prop::Rng;
use std::path::Path;

/// The fault-plan format version this build reads and writes.
/// [`FaultPlan::from_json`] rejects any other value with the version it
/// found and the supported range.
pub const FAULT_VERSION: usize = 1;

/// Loss of (part of) the board at a point in time.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BoardLoss {
    /// When the loss happens, in seconds from the start of the simulated
    /// horizon. The fault simulator serves frames up to this instant and
    /// reports the truncated effective rate.
    pub at_s: f64,
    /// Fraction of every fabric resource (DSP, LUT, FF, BRAM) that
    /// survives, in `(0, 1]` — the capacity [`crate::plan::Planner::replan`]
    /// re-admits displaced tenants against. `1.0` models a transient
    /// outage with full capacity after recovery.
    pub survive_frac: f64,
}

/// Partial-reconfiguration misbehavior.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ReconfigFault {
    /// Multiplier (`≥ 1`) on every slice's partial-bitstream swap cost —
    /// a congested or throttled configuration port.
    pub overrun_factor: f64,
    /// Per-slice probability in `[0, 1]` that a swap fails verification
    /// and is streamed again (doubling that slice's cost). Drawn from the
    /// fault plan's seeded PRNG — deterministic per seed.
    pub failure_prob: f64,
}

/// A transient backend error burst on the serving path: execute calls
/// `start .. start+length` (0-based, counted after warm-up) fail once
/// each. The coordinator's bounded-retry hardening is tested against
/// exactly this shape; the DES ignores it.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ErrorBurst {
    /// Index of the first failing backend call.
    pub start: usize,
    /// Number of consecutive failing calls.
    pub length: usize,
}

/// A typed, seeded, serializable fault scenario. All fields are optional —
/// an empty fault plan ([`FaultPlan::none`]) injects nothing and the
/// faulted simulation reproduces the healthy one exactly
/// (regression-pinned).
#[derive(Debug, Clone, PartialEq)]
pub struct FaultPlan {
    /// Seed for every stochastic choice (reconfiguration failure coins).
    /// The same seed always produces the same injected fault sequence.
    pub seed: u64,
    /// Board loss at a point in time (see [`BoardLoss`]).
    pub board_loss: Option<BoardLoss>,
    /// DDR bandwidth degradation factor in `(0, 1]`: the port runs at
    /// `factor ×` its rated bytes/second.
    pub ddr_factor: Option<f64>,
    /// Reconfiguration overrun/failure (see [`ReconfigFault`]).
    pub reconfig: Option<ReconfigFault>,
    /// Transient backend error burst for the serving path (see
    /// [`ErrorBurst`]).
    pub backend_errors: Option<ErrorBurst>,
}

impl FaultPlan {
    /// The neutral fault plan: nothing is injected.
    pub fn none() -> FaultPlan {
        FaultPlan {
            seed: 0,
            board_loss: None,
            ddr_factor: None,
            reconfig: None,
            backend_errors: None,
        }
    }

    /// Reject nonphysical fault parameters with the real cause.
    pub fn validate(&self) -> crate::Result<()> {
        if let Some(l) = &self.board_loss {
            anyhow::ensure!(
                l.at_s >= 0.0 && l.at_s.is_finite(),
                "board_loss.at_s must be a finite non-negative time, got {}",
                l.at_s
            );
            anyhow::ensure!(
                l.survive_frac > 0.0 && l.survive_frac <= 1.0,
                "board_loss.survive_frac must be in (0, 1], got {}",
                l.survive_frac
            );
        }
        if let Some(f) = self.ddr_factor {
            anyhow::ensure!(
                f > 0.0 && f <= 1.0,
                "ddr_factor must be in (0, 1], got {f}"
            );
        }
        if let Some(r) = &self.reconfig {
            anyhow::ensure!(
                r.overrun_factor >= 1.0 && r.overrun_factor.is_finite(),
                "reconfig.overrun_factor must be ≥ 1 (an overrun never shortens a swap), got {}",
                r.overrun_factor
            );
            anyhow::ensure!(
                (0.0..=1.0).contains(&r.failure_prob),
                "reconfig.failure_prob must be in [0, 1], got {}",
                r.failure_prob
            );
        }
        Ok(())
    }

    /// The board capacity that survives this fault: fabric resources
    /// scaled by [`BoardLoss::survive_frac`] (rounded down), the DDR port
    /// by [`FaultPlan::ddr_factor`]. This is what
    /// [`crate::plan::Planner::replan`] re-admits tenants against.
    pub fn surviving_board(&self, board: &Board) -> Board {
        let frac = self.board_loss.map_or(1.0, |l| l.survive_frac);
        let scale = |x: usize| (x as f64 * frac).floor() as usize;
        Board {
            name: board.name.clone(),
            dsps: scale(board.dsps),
            luts: scale(board.luts),
            ffs: scale(board.ffs),
            bram36: scale(board.bram36),
            ddr_bytes_per_sec: board.ddr_bytes_per_sec * self.ddr_factor.unwrap_or(1.0),
            freq_hz: board.freq_hz,
        }
    }

    /// The board the *deployed* bitstream keeps running on under this
    /// fault: full fabric (a committed pipeline cannot partially survive
    /// — loss is handled as an outage in time, not a capacity cut), DDR
    /// port scaled by the brownout factor.
    pub fn degraded_port(&self, board: &Board) -> Board {
        let mut b = board.clone();
        b.ddr_bytes_per_sec *= self.ddr_factor.unwrap_or(1.0);
        b
    }

    /// Inject the reconfiguration fault into a schedule: every slice's
    /// swap cost is multiplied by the overrun factor, then a seeded
    /// per-slice coin with `failure_prob` doubles it (failed swap →
    /// streamed again). Deterministic per [`FaultPlan::seed`]; with no
    /// reconfiguration fault the schedule is returned unchanged.
    pub fn degraded_schedule(&self, seq: &[ScheduleSlice]) -> Vec<ScheduleSlice> {
        let Some(rf) = &self.reconfig else {
            return seq.to_vec();
        };
        let mut rng = Rng::new(self.seed);
        seq.iter()
            .map(|s| {
                let mut rc = (s.reconfig_cycles as f64 * rf.overrun_factor).ceil() as u64;
                // One coin per slice, drawn even for zero-cost slices so
                // the stream stays aligned across schedule variants.
                if unit(rng.next_u64()) < rf.failure_prob {
                    rc *= 2;
                }
                ScheduleSlice {
                    tenant: s.tenant,
                    frames: s.frames,
                    slice_cycles: s.slice_cycles,
                    reconfig_cycles: rc,
                }
            })
            .collect()
    }

    /// Serialize to the versioned JSON fault format (deterministic field
    /// order, bit-exact floats).
    pub fn to_json(&self) -> Value {
        let mut pairs = vec![
            ("version", num(FAULT_VERSION)),
            ("seed", Value::Num(self.seed as f64)),
        ];
        if let Some(l) = &self.board_loss {
            pairs.push((
                "board_loss",
                obj(vec![
                    ("at_s", Value::Num(l.at_s)),
                    ("survive_frac", Value::Num(l.survive_frac)),
                ]),
            ));
        }
        if let Some(f) = self.ddr_factor {
            pairs.push(("ddr_factor", Value::Num(f)));
        }
        if let Some(r) = &self.reconfig {
            pairs.push((
                "reconfig",
                obj(vec![
                    ("overrun_factor", Value::Num(r.overrun_factor)),
                    ("failure_prob", Value::Num(r.failure_prob)),
                ]),
            ));
        }
        if let Some(b) = &self.backend_errors {
            pairs.push((
                "backend_errors",
                obj(vec![("start", num(b.start)), ("length", num(b.length))]),
            ));
        }
        obj(pairs)
    }

    /// Deserialize from the versioned JSON fault format. Unknown versions
    /// are rejected with the version found and the supported range.
    pub fn from_json(v: &Value) -> crate::Result<FaultPlan> {
        let version = v.usize_field("version")?;
        anyhow::ensure!(
            version == FAULT_VERSION,
            "unsupported fault-plan version {version}: this build reads versions \
             {FAULT_VERSION}..={FAULT_VERSION}"
        );
        let seed = v
            .req("seed")?
            .as_f64()
            .filter(|n| *n >= 0.0 && n.fract() == 0.0)
            .map(|n| n as u64)
            .ok_or_else(|| anyhow::anyhow!("'seed' must be a non-negative integer"))?;
        let board_loss = match v.get("board_loss") {
            None => None,
            Some(l) => Some(BoardLoss {
                at_s: l.f64_field("at_s")?,
                survive_frac: l.f64_field("survive_frac")?,
            }),
        };
        let ddr_factor = match v.get("ddr_factor") {
            None => None,
            Some(f) => Some(
                f.as_f64()
                    .ok_or_else(|| anyhow::anyhow!("'ddr_factor' must be a number"))?,
            ),
        };
        let reconfig = match v.get("reconfig") {
            None => None,
            Some(r) => Some(ReconfigFault {
                overrun_factor: r.f64_field("overrun_factor")?,
                failure_prob: r.f64_field("failure_prob")?,
            }),
        };
        let backend_errors = match v.get("backend_errors") {
            None => None,
            Some(b) => Some(ErrorBurst {
                start: b.usize_field("start")?,
                length: b.usize_field("length")?,
            }),
        };
        let plan = FaultPlan {
            seed,
            board_loss,
            ddr_factor,
            reconfig,
            backend_errors,
        };
        plan.validate()?;
        Ok(plan)
    }

    /// Write the fault plan to a file (pretty-printed JSON).
    pub fn save(&self, path: impl AsRef<Path>) -> crate::Result<()> {
        std::fs::write(path.as_ref(), self.to_json().to_pretty())?;
        Ok(())
    }

    /// Load a fault plan from a file; errors carry the path.
    pub fn load(path: impl AsRef<Path>) -> crate::Result<FaultPlan> {
        let text = std::fs::read_to_string(path.as_ref())
            .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.as_ref().display()))?;
        let v = json::parse(&text)
            .map_err(|e| anyhow::anyhow!("{}: {e}", path.as_ref().display()))?;
        FaultPlan::from_json(&v).map_err(|e| anyhow::anyhow!("{}: {e}", path.as_ref().display()))
    }
}

/// Map a raw PRNG draw to the unit interval `[0, 1)` (53 mantissa bits).
fn unit(x: u64) -> f64 {
    (x >> 11) as f64 / (1u64 << 53) as f64
}

// ---------------------------------------------------------------------------
// Fault-injected simulation report
// ---------------------------------------------------------------------------

/// One tenant's measurements under a fault scenario.
#[derive(Debug, Clone)]
pub struct FaultTenantReport {
    /// Tenant model name (plan order preserved in the parent report).
    pub net: String,
    /// Effective fps of the healthy plan (no faults) — the baseline the
    /// degradation is measured against.
    pub healthy_fps: f64,
    /// Effective fps of the *running* faulted fabric: DDR brownout and
    /// reconfiguration overruns applied, outage truncation not yet.
    pub degraded_fps: f64,
    /// Effective fps over the whole horizon: `degraded_fps ×
    /// served_frac` — what the tenant actually gets when the board dies
    /// at [`BoardLoss::at_s`].
    pub fps: f64,
    /// Worst-case frame sojourn of the faulted fabric in seconds
    /// (measured by the DES: schedule worst sojourn for temporal plans,
    /// first-frame completion for resident pipelines).
    pub sojourn_s: f64,
    /// Fraction of the simulated horizon the board served before the
    /// loss (`1.0` with no board loss or a loss beyond the horizon).
    pub served_frac: f64,
}

/// Per-tenant fps/sojourn under a [`FaultPlan`] — the output of
/// [`crate::sim::Simulator::simulate_faulted`]. Serializes to
/// deterministic JSON: the same plan, faults, and seed produce
/// byte-identical reports (CI runs the simulation twice and diffs them).
#[derive(Debug, Clone)]
pub struct FaultSimReport {
    /// The fault plan's seed (echoed for reproduction).
    pub seed: u64,
    /// The executed plan's sharing regime label.
    pub regime: String,
    /// Simulated horizon in seconds: the executed window the loss instant
    /// is interpreted against (one schedule period for temporal plans,
    /// the longest tenant makespan for resident plans).
    pub horizon_s: f64,
    /// One entry per tenant, in plan order.
    pub tenants: Vec<FaultTenantReport>,
}

impl FaultSimReport {
    /// Deterministic JSON document (sorted keys, bit-exact floats).
    pub fn to_json(&self) -> Value {
        obj(vec![
            ("version", num(FAULT_VERSION)),
            ("seed", Value::Num(self.seed as f64)),
            ("regime", Value::Str(self.regime.clone())),
            ("horizon_s", Value::Num(self.horizon_s)),
            (
                "tenants",
                Value::Arr(
                    self.tenants
                        .iter()
                        .map(|t| {
                            obj(vec![
                                ("net", Value::Str(t.net.clone())),
                                ("healthy_fps", Value::Num(t.healthy_fps)),
                                ("degraded_fps", Value::Num(t.degraded_fps)),
                                ("fps", Value::Num(t.fps)),
                                ("sojourn_s", Value::Num(t.sojourn_s)),
                                ("served_frac", Value::Num(t.served_frac)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

// ---------------------------------------------------------------------------
// Plan diffing: the typed delta between two deployments
// ---------------------------------------------------------------------------

/// One reconfiguration action of a [`PlanDiff`]: stream the target
/// tenant's partial bitstream, crediting what hides under the outgoing
/// pipeline's drain tail (the PR-4 drain-credit machinery,
/// [`crate::shard::drain_credit`]).
#[derive(Debug, Clone)]
pub struct ReconfigStep {
    /// Incoming tenant's model name.
    pub net: String,
    /// Full partial-bitstream swap cost in cycles (no credit).
    pub full_cycles: u64,
    /// Cycles hidden under the outgoing tenant's drain tail
    /// (`min(full, measured drain)`; 0 for added tenants — there is no
    /// outgoing pipeline to drain).
    pub overlap_cycles: u64,
}

impl ReconfigStep {
    /// Dead cycles actually charged: `full − overlap`.
    pub fn charged_cycles(&self) -> u64 {
        self.full_cycles - self.overlap_cycles
    }
}

/// One target-plan tenant's relationship to the source plan, in target
/// plan order.
#[derive(Debug, Clone)]
pub enum TenantOp {
    /// Byte-identical tenant carried over from source index `from` — no
    /// reconfiguration.
    Keep {
        /// Index of this tenant in the source plan.
        from: usize,
    },
    /// Same model, different θ/α/share/record — the region is swapped
    /// with a drain-overlapped reconfiguration.
    Change {
        /// Index of the outgoing tenant in the source plan.
        from: usize,
        /// The tenant as the target plan declares it (authoritative —
        /// [`DeploymentPlan::apply`] reproduces the target byte-for-byte
        /// from these payloads).
        tenant: PlanTenant,
        /// The swap executing this change.
        reconfig: ReconfigStep,
    },
    /// Tenant present only in the target plan — a full, uncredited swap.
    Add {
        /// The tenant as the target plan declares it.
        tenant: PlanTenant,
        /// The swap bringing the tenant in (no drain credit).
        reconfig: ReconfigStep,
    },
}

/// A source-plan tenant absent from the target plan. Dropping a region
/// costs no reconfiguration (nothing is streamed in).
#[derive(Debug, Clone)]
pub struct RemovedTenant {
    /// Index of the dropped tenant in the source plan.
    pub from: usize,
    /// Its model name.
    pub net: String,
}

/// The typed delta between two [`DeploymentPlan`]s: per-tenant operations
/// in target order, dropped tenants, and whichever plan-level fields
/// changed. Produced by [`DeploymentPlan::diff`]; executed (in data) by
/// [`DeploymentPlan::apply`] and (live) by
/// [`crate::coordinator::PlannedService::apply`].
///
/// Algebra (property-pinned in `tests/plan_diff.rs`):
/// `diff(a, a).is_empty()`; `a.apply(&a.diff(&b)?)?` serializes
/// byte-identically to `b`; and [`PlanDiff::cost_cycles`] is bounded by
/// the target plan's full-swap cost in both directions.
#[derive(Debug, Clone)]
pub struct PlanDiff {
    /// One op per target-plan tenant, in target plan order.
    pub ops: Vec<TenantOp>,
    /// Source tenants absent from the target, in source order.
    pub removed: Vec<RemovedTenant>,
    /// Target board when it differs from the source's.
    pub board: Option<Board>,
    /// Target quantization mode when it differs.
    pub mode: Option<crate::quant::QuantMode>,
    /// Target split granularity when it differs.
    pub steps: Option<usize>,
    /// Target sharing regime (with its full temporal layout) when it
    /// differs.
    pub regime: Option<crate::shard::Regime>,
    /// Target reconfiguration cost model when it differs.
    pub reconfig_model: Option<crate::shard::ReconfigModel>,
}

impl PlanDiff {
    /// No tenant changed, moved, or was added/removed, and every
    /// plan-level field is identical.
    pub fn is_empty(&self) -> bool {
        self.removed.is_empty()
            && self.board.is_none()
            && self.mode.is_none()
            && self.steps.is_none()
            && self.regime.is_none()
            && self.reconfig_model.is_none()
            && self
                .ops
                .iter()
                .enumerate()
                .all(|(j, op)| matches!(op, TenantOp::Keep { from } if *from == j))
    }

    /// Total reconfiguration dead cycles the transition charges: the sum
    /// of every change/add swap's `full − overlap`. Kept tenants and
    /// removed tenants cost nothing.
    pub fn cost_cycles(&self) -> u64 {
        self.ops
            .iter()
            .map(|op| match op {
                TenantOp::Keep { .. } => 0,
                TenantOp::Change { reconfig, .. } | TenantOp::Add { reconfig, .. } => {
                    reconfig.charged_cycles()
                }
            })
            .sum()
    }

    /// Summary JSON for `flexipipe plan --diff` (deterministic field
    /// order). Carries op kinds, per-swap costs, and which plan-level
    /// fields changed — not the full tenant payloads (those live in the
    /// target plan file itself).
    pub fn to_json(&self) -> Value {
        let ops: Vec<Value> = self
            .ops
            .iter()
            .map(|op| match op {
                TenantOp::Keep { from } => obj(vec![
                    ("op", Value::Str("keep".to_string())),
                    ("from", num(*from)),
                ]),
                TenantOp::Change {
                    from,
                    tenant,
                    reconfig,
                } => obj(vec![
                    ("op", Value::Str("change".to_string())),
                    ("from", num(*from)),
                    ("net", Value::Str(tenant.net.name.clone())),
                    ("full_cycles", Value::Num(reconfig.full_cycles as f64)),
                    ("overlap_cycles", Value::Num(reconfig.overlap_cycles as f64)),
                    ("charged_cycles", Value::Num(reconfig.charged_cycles() as f64)),
                ]),
                TenantOp::Add { tenant, reconfig } => obj(vec![
                    ("op", Value::Str("add".to_string())),
                    ("net", Value::Str(tenant.net.name.clone())),
                    ("full_cycles", Value::Num(reconfig.full_cycles as f64)),
                    ("overlap_cycles", Value::Num(reconfig.overlap_cycles as f64)),
                    ("charged_cycles", Value::Num(reconfig.charged_cycles() as f64)),
                ]),
            })
            .collect();
        let removed: Vec<Value> = self
            .removed
            .iter()
            .map(|r| {
                obj(vec![
                    ("from", num(r.from)),
                    ("net", Value::Str(r.net.clone())),
                ])
            })
            .collect();
        obj(vec![
            ("empty", Value::Bool(self.is_empty())),
            ("cost_cycles", Value::Num(self.cost_cycles() as f64)),
            ("ops", Value::Arr(ops)),
            ("removed", Value::Arr(removed)),
            ("board_changed", Value::Bool(self.board.is_some())),
            ("mode_changed", Value::Bool(self.mode.is_some())),
            ("steps_changed", Value::Bool(self.steps.is_some())),
            ("regime_changed", Value::Bool(self.regime.is_some())),
            (
                "reconfig_model_changed",
                Value::Bool(self.reconfig_model.is_some()),
            ),
        ])
    }

    /// Full wire codec, the `POST /plan/apply` body format: unlike the
    /// summary [`PlanDiff::to_json`], this carries the complete tenant
    /// payloads and plan-level overrides, so a receiving service can
    /// execute the diff with [`DeploymentPlan::apply`] (or live with
    /// [`crate::coordinator::PlannedService::apply`]) without ever
    /// seeing the target plan file. Versioned like the other formats;
    /// [`PlanDiff::from_wire_json`] rejects anything but
    /// [`DIFF_WIRE_VERSION`]. Deterministic field order: encoding the
    /// same diff twice is byte-identical, and optional overrides are
    /// omitted (not nulled) when unchanged.
    pub fn to_wire_json(&self) -> Value {
        let ops: Vec<Value> = self
            .ops
            .iter()
            .map(|op| match op {
                TenantOp::Keep { from } => obj(vec![
                    ("op", Value::Str("keep".to_string())),
                    ("from", num(*from)),
                ]),
                TenantOp::Change {
                    from,
                    tenant,
                    reconfig,
                } => obj(vec![
                    ("op", Value::Str("change".to_string())),
                    ("from", num(*from)),
                    ("tenant", crate::plan::tenant_to_json(tenant)),
                    ("reconfig", reconfig_step_to_json(reconfig)),
                ]),
                TenantOp::Add { tenant, reconfig } => obj(vec![
                    ("op", Value::Str("add".to_string())),
                    ("tenant", crate::plan::tenant_to_json(tenant)),
                    ("reconfig", reconfig_step_to_json(reconfig)),
                ]),
            })
            .collect();
        let removed: Vec<Value> = self
            .removed
            .iter()
            .map(|r| {
                obj(vec![
                    ("from", num(r.from)),
                    ("net", Value::Str(r.net.clone())),
                ])
            })
            .collect();
        let mut pairs = vec![
            ("version", num(DIFF_WIRE_VERSION)),
            ("ops", Value::Arr(ops)),
            ("removed", Value::Arr(removed)),
        ];
        if let Some(b) = &self.board {
            pairs.push(("board", crate::plan::board_to_json(b)));
        }
        if let Some(m) = &self.mode {
            pairs.push(("bits", num(m.bits())));
        }
        if let Some(s) = self.steps {
            pairs.push(("steps", num(s)));
        }
        if let Some(r) = &self.regime {
            pairs.push(("regime", Value::Str(r.label().to_string())));
            if let crate::shard::Regime::Temporal(info) = r {
                pairs.push(("temporal", crate::plan::temporal_to_json(info)));
            }
        }
        if let Some(m) = &self.reconfig_model {
            pairs.push(("reconfig_model", crate::plan::reconfig_to_json(m)));
        }
        obj(pairs)
    }

    /// Decode a diff from its wire format (see
    /// [`PlanDiff::to_wire_json`]). Structural validation happens here
    /// (known ops, integer indices, overlap ≤ full); *semantic*
    /// validation — source indices in range, each claimed once —
    /// happens in [`DeploymentPlan::apply`], exactly as for a
    /// locally-computed diff.
    pub fn from_wire_json(v: &Value) -> crate::Result<PlanDiff> {
        let version = v.usize_field("version")?;
        anyhow::ensure!(
            version == DIFF_WIRE_VERSION,
            "unsupported plan-diff wire version {version}: this build reads versions \
             {DIFF_WIRE_VERSION}..={DIFF_WIRE_VERSION}"
        );
        let ops = v
            .req("ops")?
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("'ops' must be an array"))?
            .iter()
            .map(|o| -> crate::Result<TenantOp> {
                Ok(match o.str_field("op")? {
                    "keep" => TenantOp::Keep {
                        from: o.usize_field("from")?,
                    },
                    "change" => TenantOp::Change {
                        from: o.usize_field("from")?,
                        tenant: crate::plan::tenant_from_json(o.req("tenant")?)?,
                        reconfig: reconfig_step_from_json(o.req("reconfig")?)?,
                    },
                    "add" => TenantOp::Add {
                        tenant: crate::plan::tenant_from_json(o.req("tenant")?)?,
                        reconfig: reconfig_step_from_json(o.req("reconfig")?)?,
                    },
                    other => anyhow::bail!("unknown diff op '{other}' (keep change add)"),
                })
            })
            .collect::<crate::Result<Vec<_>>>()?;
        let removed = v
            .req("removed")?
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("'removed' must be an array"))?
            .iter()
            .map(|r| -> crate::Result<RemovedTenant> {
                Ok(RemovedTenant {
                    from: r.usize_field("from")?,
                    net: r.str_field("net")?.to_string(),
                })
            })
            .collect::<crate::Result<Vec<_>>>()?;
        let board = match v.get("board") {
            None => None,
            Some(b) => Some(crate::plan::board_from_json(b)?),
        };
        let mode = match v.get("bits") {
            None => None,
            Some(b) => Some(crate::quant::QuantMode::from_bits(
                b.as_usize()
                    .ok_or_else(|| anyhow::anyhow!("'bits' must be an integer"))?,
            )?),
        };
        let steps = match v.get("steps") {
            None => None,
            Some(s) => Some(
                s.as_usize()
                    .ok_or_else(|| anyhow::anyhow!("'steps' must be an integer"))?,
            ),
        };
        let regime = match v.get("regime") {
            None => {
                anyhow::ensure!(
                    v.get("temporal").is_none(),
                    "diff carries a 'temporal' section without a 'regime'"
                );
                None
            }
            Some(r) => {
                let label = r
                    .as_str()
                    .ok_or_else(|| anyhow::anyhow!("'regime' must be a string"))?;
                Some(match label {
                    "spatial" => {
                        anyhow::ensure!(
                            v.get("temporal").is_none(),
                            "spatial diff regime carries a 'temporal' section"
                        );
                        crate::shard::Regime::Spatial
                    }
                    "temporal" | "overlay" => {
                        let info = crate::plan::temporal_from_json(v.req("temporal")?)?;
                        anyhow::ensure!(
                            (label == "overlay") == info.overlay,
                            "regime label '{label}' contradicts the schedule's overlay flag"
                        );
                        crate::shard::Regime::Temporal(info)
                    }
                    other => anyhow::bail!("unknown regime '{other}' (spatial temporal overlay)"),
                })
            }
        };
        let reconfig_model = match v.get("reconfig_model") {
            None => None,
            Some(m) => Some(crate::plan::reconfig_from_json(m)?),
        };
        Ok(PlanDiff {
            ops,
            removed,
            board,
            mode,
            steps,
            regime,
            reconfig_model,
        })
    }
}

/// Wire-format version written by [`PlanDiff::to_wire_json`];
/// [`PlanDiff::from_wire_json`] rejects anything else.
pub const DIFF_WIRE_VERSION: usize = 1;

fn reconfig_step_to_json(r: &ReconfigStep) -> Value {
    obj(vec![
        ("net", Value::Str(r.net.clone())),
        ("full_cycles", Value::Num(r.full_cycles as f64)),
        ("overlap_cycles", Value::Num(r.overlap_cycles as f64)),
    ])
}

fn reconfig_step_from_json(v: &Value) -> crate::Result<ReconfigStep> {
    let cycles = |key: &str| -> crate::Result<u64> {
        v.req(key)?
            .as_f64()
            .filter(|n| *n >= 0.0 && n.fract() == 0.0)
            .map(|n| n as u64)
            .ok_or_else(|| anyhow::anyhow!("'{key}' must be a non-negative integer"))
    };
    let full_cycles = cycles("full_cycles")?;
    let overlap_cycles = cycles("overlap_cycles")?;
    anyhow::ensure!(
        overlap_cycles <= full_cycles,
        "reconfig overlap_cycles {overlap_cycles} exceeds full_cycles {full_cycles}"
    );
    Ok(ReconfigStep {
        net: v.str_field("net")?.to_string(),
        full_cycles,
        overlap_cycles,
    })
}

/// Frames of the short solo DES run that measures an outgoing pipeline's
/// drain tail for the diff's overlap credit — the same conservative
/// minimum-over-window rule the temporal planner calibrates with.
const DIFF_DRAIN_FRAMES: usize = 2;

/// Compute the typed delta from `from` to `to` (see [`PlanDiff`]).
///
/// Tenants are matched by model name and occurrence (the `k`-th `lenet`
/// of the source pairs with the `k`-th `lenet` of the target), so
/// workloads with repeated models diff stably. When any tenant changes or
/// is added, both plans are instantiated to price the swaps: the target
/// tenant's allocation gives the partial-bitstream cost under the target
/// plan's [`crate::shard::ReconfigModel`], and the outgoing tenant's
/// measured drain tail gives the overlap credit.
pub fn diff(from: &DeploymentPlan, to: &DeploymentPlan) -> crate::Result<PlanDiff> {
    anyhow::ensure!(
        from.version == to.version,
        "cannot diff plans of different format versions ({} vs {})",
        from.version,
        to.version
    );
    let tenant_text = |t: &PlanTenant| crate::plan::tenant_to_json(t).to_pretty();
    let from_text: Vec<String> = from.tenants.iter().map(tenant_text).collect();
    let to_text: Vec<String> = to.tenants.iter().map(tenant_text).collect();

    // Match target tenants to source tenants by (name, occurrence).
    let mut matched = vec![false; from.tenants.len()];
    let mut pairing: Vec<Option<usize>> = Vec::with_capacity(to.tenants.len());
    for (j, t) in to.tenants.iter().enumerate() {
        let occ = to.tenants[..j]
            .iter()
            .filter(|x| x.net.name == t.net.name)
            .count();
        let src = from
            .tenants
            .iter()
            .enumerate()
            .filter(|(_, x)| x.net.name == t.net.name)
            .nth(occ)
            .map(|(i, _)| i);
        if let Some(i) = src {
            matched[i] = true;
        }
        pairing.push(src);
    }

    // Price the swaps only when something actually changes (identical
    // plans diff without rehydrating anything).
    let needs_cost = pairing.iter().enumerate().any(|(j, src)| match src {
        Some(i) => from_text[*i] != to_text[j],
        None => true,
    });
    let (from_allocs, to_allocs) = if needs_cost {
        (from.instantiate()?, to.instantiate()?)
    } else {
        (Vec::new(), Vec::new())
    };

    let mut ops = Vec::with_capacity(to.tenants.len());
    for (j, src) in pairing.iter().enumerate() {
        match src {
            Some(i) if from_text[*i] == to_text[j] => ops.push(TenantOp::Keep { from: *i }),
            Some(i) => {
                let full = to
                    .reconfig
                    .cycles(&to_allocs[j].evaluate(), to.board.freq_hz);
                let drain = crate::shard::drain_credit(&from_allocs[*i], DIFF_DRAIN_FRAMES);
                ops.push(TenantOp::Change {
                    from: *i,
                    tenant: to.tenants[j].clone(),
                    reconfig: ReconfigStep {
                        net: to.tenants[j].net.name.clone(),
                        full_cycles: full,
                        overlap_cycles: full.min(drain),
                    },
                });
            }
            None => {
                let full = to
                    .reconfig
                    .cycles(&to_allocs[j].evaluate(), to.board.freq_hz);
                ops.push(TenantOp::Add {
                    tenant: to.tenants[j].clone(),
                    reconfig: ReconfigStep {
                        net: to.tenants[j].net.name.clone(),
                        full_cycles: full,
                        overlap_cycles: 0,
                    },
                });
            }
        }
    }
    let removed = (0..from.tenants.len())
        .filter(|&i| !matched[i])
        .map(|i| RemovedTenant {
            from: i,
            net: from.tenants[i].net.name.clone(),
        })
        .collect();

    // Plan-level deltas, detected on the serialized form so the
    // comparison can never drift from what apply() reconstructs.
    let changed = |a: Value, b: Value| (a.to_pretty() != b.to_pretty());
    let board = changed(
        crate::plan::board_to_json(&from.board),
        crate::plan::board_to_json(&to.board),
    )
    .then(|| to.board.clone());
    let mode = (from.mode != to.mode).then_some(to.mode);
    let steps = (from.steps != to.steps).then_some(to.steps);
    let regime = changed(regime_value(from), regime_value(to)).then(|| to.regime.clone());
    let reconfig_model = changed(
        crate::plan::reconfig_to_json(&from.reconfig),
        crate::plan::reconfig_to_json(&to.reconfig),
    )
    .then(|| to.reconfig.clone());

    Ok(PlanDiff {
        ops,
        removed,
        board,
        mode,
        steps,
        regime,
        reconfig_model,
    })
}

/// Serialized regime identity (label + full temporal layout when present).
fn regime_value(p: &DeploymentPlan) -> Value {
    let mut pairs = vec![("label", Value::Str(p.regime.label().to_string()))];
    if let crate::shard::Regime::Temporal(info) = &p.regime {
        pairs.push(("temporal", crate::plan::temporal_to_json(info)));
    }
    obj(pairs)
}

impl DeploymentPlan {
    /// Typed delta from `self` to `target` — see [`diff`].
    pub fn diff(&self, target: &DeploymentPlan) -> crate::Result<PlanDiff> {
        diff(self, target)
    }

    /// Reconstruct the target plan a diff describes: kept tenants are
    /// copied from `self`, changed/added tenants come from the diff's
    /// payloads, and changed plan-level fields override `self`'s.
    /// `a.apply(&a.diff(&b)?)?` serializes byte-identically to `b`
    /// (property-pinned).
    pub fn apply(&self, diff: &PlanDiff) -> crate::Result<DeploymentPlan> {
        let mut used = vec![false; self.tenants.len()];
        let mut claim = |from: usize| -> crate::Result<()> {
            anyhow::ensure!(
                from < self.tenants.len(),
                "diff references source tenant {from} but the plan has {}",
                self.tenants.len()
            );
            anyhow::ensure!(
                !used[from],
                "diff references source tenant {from} more than once"
            );
            used[from] = true;
            Ok(())
        };
        let mut tenants = Vec::with_capacity(diff.ops.len());
        for op in &diff.ops {
            match op {
                TenantOp::Keep { from } => {
                    claim(*from)?;
                    tenants.push(self.tenants[*from].clone());
                }
                TenantOp::Change { from, tenant, .. } => {
                    claim(*from)?;
                    tenants.push(tenant.clone());
                }
                TenantOp::Add { tenant, .. } => tenants.push(tenant.clone()),
            }
        }
        anyhow::ensure!(!tenants.is_empty(), "applying the diff leaves no tenants");
        Ok(DeploymentPlan {
            version: self.version,
            board: diff.board.clone().unwrap_or_else(|| self.board.clone()),
            mode: diff.mode.unwrap_or(self.mode),
            steps: diff.steps.unwrap_or(self.steps),
            tenants,
            regime: diff.regime.clone().unwrap_or_else(|| self.regime.clone()),
            reconfig: diff
                .reconfig_model
                .clone()
                .unwrap_or_else(|| self.reconfig.clone()),
        })
    }

    /// The full-swap reconfiguration cost of this plan in cycles: stream
    /// every tenant's partial bitstream with no drain credit — the upper
    /// bound any diff *into* this plan is charged under (property-pinned:
    /// `diff(a, b).cost_cycles() ≤ b.full_swap_cycles()` and
    /// symmetrically).
    pub fn full_swap_cycles(&self) -> crate::Result<u64> {
        let allocs = self.instantiate()?;
        Ok(allocs
            .iter()
            .map(|a| self.reconfig.cycles(&a.evaluate(), self.board.freq_hz))
            .sum())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::board::zc706;

    fn full_fault() -> FaultPlan {
        FaultPlan {
            seed: 42,
            board_loss: Some(BoardLoss {
                at_s: 0.25,
                survive_frac: 0.875,
            }),
            ddr_factor: Some(0.9),
            reconfig: Some(ReconfigFault {
                overrun_factor: 2.0,
                failure_prob: 0.25,
            }),
            backend_errors: Some(ErrorBurst {
                start: 1,
                length: 2,
            }),
        }
    }

    #[test]
    fn fault_plan_json_round_trips_byte_stably() {
        for plan in [FaultPlan::none(), full_fault()] {
            let text = plan.to_json().to_pretty();
            let back = FaultPlan::from_json(&json::parse(&text).unwrap()).unwrap();
            assert_eq!(plan, back);
            assert_eq!(text, back.to_json().to_pretty(), "serialization not stable");
        }
    }

    #[test]
    fn fault_plan_versions_and_ranges_are_enforced() {
        let text = full_fault().to_json().to_pretty();
        let bumped = text.replacen("\"version\": 1", "\"version\": 9", 1);
        assert_ne!(text, bumped);
        let err = FaultPlan::from_json(&json::parse(&bumped).unwrap()).unwrap_err();
        assert!(err.to_string().contains("version 9"), "{err}");
        assert!(err.to_string().contains("1..=1"), "{err}");

        let bad = |mutate: fn(&mut FaultPlan)| {
            let mut f = full_fault();
            mutate(&mut f);
            f.validate().unwrap_err()
        };
        bad(|f| f.board_loss.as_mut().unwrap().survive_frac = 0.0);
        bad(|f| f.board_loss.as_mut().unwrap().survive_frac = 1.5);
        bad(|f| f.board_loss.as_mut().unwrap().at_s = -1.0);
        bad(|f| f.ddr_factor = Some(0.0));
        bad(|f| f.ddr_factor = Some(2.0));
        bad(|f| f.reconfig.as_mut().unwrap().overrun_factor = 0.5);
        bad(|f| f.reconfig.as_mut().unwrap().failure_prob = 1.5);
        full_fault().validate().unwrap();
    }

    #[test]
    fn surviving_board_scales_fabric_and_port() {
        let b = zc706();
        let f = full_fault();
        let s = f.surviving_board(&b);
        assert_eq!(s.dsps, (b.dsps as f64 * 0.875).floor() as usize);
        assert_eq!(s.bram36, (b.bram36 as f64 * 0.875).floor() as usize);
        assert_eq!(s.luts, (b.luts as f64 * 0.875).floor() as usize);
        assert!((s.ddr_bytes_per_sec - b.ddr_bytes_per_sec * 0.9).abs() < 1e-3);
        assert_eq!(s.freq_hz, b.freq_hz);
        // The deployed bitstream keeps its fabric; only the port browns out.
        let d = f.degraded_port(&b);
        assert_eq!(d.dsps, b.dsps);
        assert!((d.ddr_bytes_per_sec - b.ddr_bytes_per_sec * 0.9).abs() < 1e-3);
        // The neutral fault changes nothing.
        let n = FaultPlan::none().surviving_board(&b);
        assert_eq!(n.dsps, b.dsps);
        assert_eq!(n.ddr_bytes_per_sec.to_bits(), b.ddr_bytes_per_sec.to_bits());
    }

    #[test]
    fn degraded_schedule_is_seeded_and_monotone() {
        let seq: Vec<ScheduleSlice> = (0..6)
            .map(|i| ScheduleSlice {
                tenant: i % 2,
                frames: 1 + i,
                slice_cycles: 1000,
                reconfig_cycles: 100 * i as u64,
            })
            .collect();
        // No reconfiguration fault: unchanged.
        let same = FaultPlan::none().degraded_schedule(&seq);
        for (a, b) in seq.iter().zip(&same) {
            assert_eq!(a.reconfig_cycles, b.reconfig_cycles);
            assert_eq!(a.frames, b.frames);
        }
        // Deterministic per seed; never below the overrun floor; failure
        // probability 1 exactly doubles the overrun cost.
        let fault = |prob: f64, seed: u64| FaultPlan {
            seed,
            reconfig: Some(ReconfigFault {
                overrun_factor: 3.0,
                failure_prob: prob,
            }),
            ..FaultPlan::none()
        };
        let a = fault(0.5, 7).degraded_schedule(&seq);
        let b = fault(0.5, 7).degraded_schedule(&seq);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.reconfig_cycles, y.reconfig_cycles, "same seed must agree");
        }
        for (s, d) in seq.iter().zip(&a) {
            let floor = s.reconfig_cycles * 3;
            assert!(d.reconfig_cycles == floor || d.reconfig_cycles == 2 * floor);
        }
        let doubled = fault(1.0, 7).degraded_schedule(&seq);
        for (s, d) in seq.iter().zip(&doubled) {
            assert_eq!(d.reconfig_cycles, s.reconfig_cycles * 6);
        }
    }

    #[test]
    fn unit_draws_stay_in_the_unit_interval() {
        let mut rng = Rng::new(3);
        for _ in 0..1000 {
            let u = unit(rng.next_u64());
            assert!((0.0..1.0).contains(&u), "{u}");
        }
    }
}
