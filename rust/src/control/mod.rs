//! Operator control plane: a dependency-free HTTP/1.1 API over a live
//! [`IngestService`].
//!
//! The module splits into two strictly separated layers:
//!
//! * **A socket-free handler core.** [`ControlPlane::handle`] maps an
//!   [`HttpRequest`] to an [`HttpResponse`] with no I/O of any kind —
//!   every route, status code, and response body is exercisable from a
//!   plain unit test. All JSON is emitted through
//!   [`crate::util::json`], whose object keys are sorted, so a response
//!   body is a deterministic function of the service state.
//! * **A thin TCP adapter.** [`serve`] runs a hand-rolled HTTP/1.1
//!   server over [`std::net::TcpListener`] and a fixed pool of worker
//!   threads — no external crates, in keeping with the repository's
//!   zero-dependency policy. The adapter only parses bytes into
//!   [`HttpRequest`]s and writes [`HttpResponse`]s back; it adds no
//!   behaviour of its own.
//!
//! Routes (all request and response bodies are JSON):
//!
//! | Method   | Path              | Semantics                                           |
//! |----------|-------------------|-----------------------------------------------------|
//! | `GET`    | `/health`         | Per-tenant health label + coordinator counters      |
//! | `GET`    | `/queues`         | Live per-tenant [`QueueStatus`] snapshot            |
//! | `GET`    | `/plan`           | The active [`DeploymentPlan`] document              |
//! | `GET`    | `/histograms[/T]` | Live latency quantiles (µs) from the log histogram  |
//! | `POST`   | `/submit`         | Enqueue a frame (priority, relative deadline)       |
//! | `GET`    | `/requests/{id}`  | Poll a submitted request (one-shot once finished)   |
//! | `DELETE` | `/requests/{id}`  | Cancel a queued request                             |
//! | `POST`   | `/plan/apply`     | Apply a [`PlanDiff`] (wire JSON) to the service     |
//! | `POST`   | `/replan`         | Failover-replan around a [`FaultPlan`] and apply    |
//! | `POST`   | `/replay`         | Deterministic [`serve_trace`] of a trace spec       |
//! | `POST`   | `/shutdown`       | Drain and stop the service (final queue snapshot)   |
//!
//! Admission rejections map onto typed status codes: `429` for
//! [`RejectReason::QueueFull`], `503` for shedding or a closed service,
//! and `408` for [`RejectReason::DeadlineExpired`] — a dead-on-arrival
//! deadline is refused before any other admission check, so the status
//! is never a coincidental `429`.
//!
//! The determinism boundary runs between `/replay` (pure
//! planned-timeline arithmetic: byte-identical responses for the same
//! spec against the same plan, on any machine) and the live endpoints,
//! whose *counters* depend on wall-clock dispatch timing. The response
//! *encodings* are deterministic everywhere; only live counter values
//! are timing-dependent.

use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{self, Receiver, TryRecvError};
use std::sync::Mutex;
use std::time::{Duration, Instant};

use crate::fault::{FaultPlan, PlanDiff};
use crate::ingest::{serve_trace, IngestService, QueueStatus, RejectReason, TraceSpec};
use crate::plan::{DeploymentPlan, Planner, ShedEntry};
use crate::shard::ScheduleMode;
use crate::util::json::{self, num, obj, Value};

/// Worker threads the TCP adapter handles connections on.
const CONTROL_WORKERS: usize = 4;

/// Largest accepted request body (a full plan diff with tenant payloads
/// is a few hundred KiB; 16 MiB leaves an order of magnitude of slack).
const MAX_BODY_BYTES: usize = 16 << 20;

/// Outstanding `/submit` receivers retained for `/requests/{id}`
/// polling; the oldest entries are evicted beyond this.
const MAX_PENDING: usize = 4096;

/// Largest accepted relative deadline (about 31 years) — bounds the
/// `Instant` arithmetic so no request body can panic the handler.
const MAX_DEADLINE_MS: f64 = 1e12;

/// Per-connection socket read/write timeout.
const IO_TIMEOUT: Duration = Duration::from_secs(10);

/// One parsed HTTP request: the method, the raw path (query strings are
/// ignored by the router), and the decoded UTF-8 body.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct HttpRequest {
    /// Request method, verbatim (`GET`, `POST`, `DELETE`, ...).
    pub method: String,
    /// Request path, verbatim (e.g. `/requests/7`).
    pub path: String,
    /// Request body (empty when the request carried none).
    pub body: String,
}

/// One HTTP response: a status code and a JSON body. The TCP adapter
/// adds the framing headers; the handler core never sees bytes.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct HttpResponse {
    /// Status code (200, 400, 404, 405, 408, 409, 429, 503).
    pub status: u16,
    /// JSON response body (pretty-printed, sorted keys).
    pub body: String,
}

impl HttpResponse {
    /// Build a response with a pretty-printed JSON body.
    pub fn json(status: u16, body: Value) -> HttpResponse {
        HttpResponse {
            status,
            body: body.to_pretty(),
        }
    }

    /// The standard reason phrase for the status code.
    pub fn reason(&self) -> &'static str {
        match self.status {
            200 => "OK",
            400 => "Bad Request",
            404 => "Not Found",
            405 => "Method Not Allowed",
            408 => "Request Timeout",
            409 => "Conflict",
            429 => "Too Many Requests",
            503 => "Service Unavailable",
            _ => "Unknown",
        }
    }
}

/// `{"error": msg}` — every non-2xx body carries the real cause.
fn err_json(status: u16, msg: &str) -> HttpResponse {
    HttpResponse::json(status, obj(vec![("error", Value::Str(msg.to_string()))]))
}

/// Counters are `u64`; JSON numbers are `f64` (exact to 2^53).
fn u64v(x: u64) -> Value {
    Value::Num(x as f64)
}

/// Response channel of one live request (the ingest dispatcher's end).
type RespRx = Receiver<crate::Result<Vec<i8>>>;

/// Mutable control-plane state: the live service (taken on shutdown)
/// and the id → receiver map backing `/requests/{id}` polling.
struct ControlState {
    svc: Option<IngestService>,
    pending: BTreeMap<u64, RespRx>,
}

/// The socket-free handler core: owns a live [`IngestService`] and maps
/// [`HttpRequest`]s to [`HttpResponse`]s. Thread-safe — all handling
/// runs behind one mutex (mutating endpoints such as `POST /plan/apply`
/// need exclusive access anyway), so the TCP adapter's worker pool
/// shares one instance by reference.
pub struct ControlPlane {
    state: Mutex<ControlState>,
    down: AtomicBool,
}

impl ControlPlane {
    /// Wrap a running service. The plane owns it from here on; `POST
    /// /shutdown` drains and consumes it.
    pub fn new(svc: IngestService) -> ControlPlane {
        ControlPlane {
            state: Mutex::new(ControlState {
                svc: Some(svc),
                pending: BTreeMap::new(),
            }),
            down: AtomicBool::new(false),
        }
    }

    /// Has `POST /shutdown` been served? The TCP adapter's accept loop
    /// exits once this reports `true`.
    pub fn is_shut_down(&self) -> bool {
        self.down.load(Ordering::SeqCst)
    }

    /// Route one request. Pure with respect to I/O: no sockets, no
    /// files — every endpoint is unit-testable in process.
    pub fn handle(&self, req: &HttpRequest) -> HttpResponse {
        let path = req.path.split('?').next().unwrap_or("");
        let segs: Vec<&str> = path.split('/').filter(|s| !s.is_empty()).collect();
        let mut st = self.state.lock().unwrap();
        match (req.method.as_str(), segs.as_slice()) {
            ("GET", ["health"]) => health(&st),
            ("GET", ["queues"]) => queues(&st),
            ("GET", ["plan"]) => active_plan(&st),
            ("GET", ["histograms"]) => histograms(&st, None),
            ("GET", ["histograms", tenant]) => histograms(&st, Some(*tenant)),
            ("GET", ["requests", id]) => poll_request(&mut st, id),
            ("DELETE", ["requests", id]) => cancel_request(&mut st, id),
            ("POST", ["submit"]) => submit(&mut st, &req.body),
            ("POST", ["plan", "apply"]) => apply_diff(&mut st, &req.body),
            ("POST", ["replan"]) => replan(&mut st, &req.body),
            ("POST", ["replay"]) => replay(&st, &req.body),
            ("POST", ["shutdown"]) => self.shutdown(&mut st),
            _ if known_path(&segs) => {
                err_json(405, &format!("method {} not allowed on {path}", req.method))
            }
            _ => err_json(404, &format!("no route for {} {path}", req.method)),
        }
    }

    /// `POST /shutdown`: drain the service (every in-flight receiver
    /// resolves) and report the final queue snapshot. Repeats after the
    /// first get the same `503` as every other post-shutdown request.
    fn shutdown(&self, st: &mut ControlState) -> HttpResponse {
        let Some(svc) = st.svc.take() else {
            return closed();
        };
        st.pending.clear();
        let final_queues = svc.shutdown();
        self.down.store(true, Ordering::SeqCst);
        HttpResponse::json(
            200,
            obj(vec![
                ("shut_down", Value::Bool(true)),
                ("queues", Value::Arr(final_queues.iter().map(queue_to_json).collect())),
            ]),
        )
    }
}

/// Does any endpoint live at this path? Routes a known path reached
/// with the wrong verb to `405` instead of `404`.
fn known_path(segs: &[&str]) -> bool {
    matches!(
        segs,
        ["health"]
            | ["queues"]
            | ["plan"]
            | ["plan", "apply"]
            | ["histograms"]
            | ["histograms", _]
            | ["requests", _]
            | ["submit"]
            | ["replan"]
            | ["replay"]
            | ["shutdown"]
    )
}

/// The uniform post-shutdown response.
fn closed() -> HttpResponse {
    err_json(503, "control plane is shut down")
}

fn queue_to_json(q: &QueueStatus) -> Value {
    obj(vec![
        ("tenant", Value::Str(q.tenant.clone())),
        ("depth", num(q.depth)),
        ("capacity", num(q.capacity)),
        ("inflight", num(q.inflight)),
        ("admitted", u64v(q.admitted)),
        ("rejected_full", u64v(q.rejected_full)),
        ("rejected_shed", u64v(q.rejected_shed)),
        ("completed", u64v(q.completed)),
        ("cancelled", u64v(q.cancelled)),
        ("expired", u64v(q.expired)),
    ])
}

fn shed_to_json(s: &ShedEntry) -> Value {
    obj(vec![
        ("net", Value::Str(s.net.clone())),
        ("reason", Value::Str(s.reason.clone())),
    ])
}

/// `GET /health`: per-tenant [`crate::coordinator::Health`] label plus
/// the coordinator's serving counters and latency quantiles.
fn health(st: &ControlState) -> HttpResponse {
    let Some(svc) = st.svc.as_ref() else {
        return closed();
    };
    let names = svc.names();
    let tenants: Vec<Value> = (0..svc.len())
        .map(|i| {
            let s = svc.stats(i);
            obj(vec![
                ("tenant", Value::Str(names[i].clone())),
                ("health", Value::Str(svc.health(i).label().to_string())),
                ("requests", u64v(s.requests)),
                ("batches", u64v(s.batches)),
                ("padded_frames", u64v(s.padded_frames)),
                ("p50_us", u64v(s.latency_us(50.0))),
                ("p99_us", u64v(s.latency_us(99.0))),
            ])
        })
        .collect();
    HttpResponse::json(200, obj(vec![("tenants", Value::Arr(tenants))]))
}

/// `GET /queues`: the live [`QueueStatus`] snapshot, plan order.
fn queues(st: &ControlState) -> HttpResponse {
    let Some(svc) = st.svc.as_ref() else {
        return closed();
    };
    let qs: Vec<Value> = svc.status().iter().map(queue_to_json).collect();
    HttpResponse::json(200, obj(vec![("queues", Value::Arr(qs))]))
}

/// `GET /plan`: the active plan's canonical JSON document.
fn active_plan(st: &ControlState) -> HttpResponse {
    let Some(svc) = st.svc.as_ref() else {
        return closed();
    };
    HttpResponse {
        status: 200,
        body: svc.plan().to_json().to_pretty(),
    }
}

/// One tenant's live latency quantiles (µs) from the 252-bucket log
/// histogram — bucket upper bounds except min/max, which are exact.
fn tenant_histogram(svc: &IngestService, name: &str, idx: usize) -> Value {
    let h = svc.histogram(idx);
    obj(vec![
        ("tenant", Value::Str(name.to_string())),
        ("count", u64v(h.count())),
        ("min_us", u64v(h.min())),
        ("p50_us", u64v(h.quantile(50.0))),
        ("p90_us", u64v(h.quantile(90.0))),
        ("p99_us", u64v(h.quantile(99.0))),
        ("p999_us", u64v(h.quantile(99.9))),
        ("max_us", u64v(h.max())),
    ])
}

/// `GET /histograms` (all tenants) and `GET /histograms/{tenant}`.
fn histograms(st: &ControlState, tenant: Option<&str>) -> HttpResponse {
    let Some(svc) = st.svc.as_ref() else {
        return closed();
    };
    let names = svc.names();
    match tenant {
        Some(t) => match names.iter().position(|n| n == t) {
            Some(i) => HttpResponse::json(200, tenant_histogram(svc, t, i)),
            None => err_json(404, &format!("unknown tenant '{t}'")),
        },
        None => {
            let all: Vec<Value> = names
                .iter()
                .enumerate()
                .map(|(i, n)| tenant_histogram(svc, n, i))
                .collect();
            HttpResponse::json(200, obj(vec![("tenants", Value::Arr(all))]))
        }
    }
}

/// Map a typed admission rejection onto its status code and body.
fn reject(r: &RejectReason) -> HttpResponse {
    let status = match r {
        RejectReason::QueueFull { .. } => 429,
        RejectReason::Shedding | RejectReason::Closed => 503,
        RejectReason::DeadlineExpired { .. } => 408,
    };
    HttpResponse::json(
        status,
        obj(vec![
            ("error", Value::Str(r.to_string())),
            ("reason", Value::Str(r.label().to_string())),
        ]),
    )
}

/// `POST /submit`: body `{"tenant": name-or-index, "priority"?: 0..=255,
/// "deadline_ms"?: relative-ms, "frame"?: [i8...]}`. An omitted frame
/// submits all zeros of the tenant's input shape; the relative deadline
/// is resolved to an absolute instant here, at admission.
fn submit(st: &mut ControlState, body: &str) -> HttpResponse {
    let ControlState { svc, pending } = st;
    let Some(svc) = svc.as_mut() else {
        return closed();
    };
    let v = match json::parse(body) {
        Ok(v) => v,
        Err(e) => return err_json(400, &format!("bad JSON body: {e}")),
    };
    let names = svc.names();
    let idx = match v.get("tenant") {
        Some(Value::Str(name)) => match names.iter().position(|n| n == name) {
            Some(i) => i,
            None => return err_json(404, &format!("unknown tenant '{name}'")),
        },
        Some(other) => match other.as_usize().filter(|i| *i < names.len()) {
            Some(i) => i,
            None => return err_json(404, "tenant index out of range"),
        },
        None => return err_json(400, "body needs a 'tenant' (name or index)"),
    };
    let priority = match v.get("priority") {
        None => 0u8,
        Some(p) => match p.as_usize().filter(|p| *p <= u8::MAX as usize) {
            Some(p) => p as u8,
            None => return err_json(400, "'priority' must be an integer in 0..=255"),
        },
    };
    let deadline = match v.get("deadline_ms") {
        None => None,
        Some(d) => match d.as_f64().filter(|ms| (0.0..=MAX_DEADLINE_MS).contains(ms)) {
            Some(ms) => Some(Instant::now() + Duration::from_secs_f64(ms / 1e3)),
            None => return err_json(400, "'deadline_ms' must be a number of ms in 0..=1e12"),
        },
    };
    let (c, h, w) = svc.plan().tenants[idx].net.input;
    let expected = c * h * w;
    let frame: Vec<i8> = match v.get("frame") {
        None => vec![0i8; expected],
        Some(f) => {
            let Some(arr) = f.as_arr() else {
                return err_json(400, "'frame' must be an array of integers");
            };
            if arr.len() != expected {
                return err_json(
                    400,
                    &format!(
                        "frame for '{}' must hold {expected} values, got {}",
                        names[idx],
                        arr.len()
                    ),
                );
            }
            let mut out = Vec::with_capacity(arr.len());
            for x in arr {
                match x.as_f64().filter(|n| n.fract() == 0.0 && (-128.0..=127.0).contains(n)) {
                    Some(n) => out.push(n as i8),
                    None => return err_json(400, "frame values must be integers in -128..=127"),
                }
            }
            out
        }
    };
    match svc.submit_with(idx, frame, priority, deadline) {
        Ok((id, rx)) => {
            while pending.len() >= MAX_PENDING {
                pending.pop_first();
            }
            pending.insert(id, rx);
            HttpResponse::json(
                200,
                obj(vec![
                    ("id", u64v(id)),
                    ("tenant", Value::Str(names[idx].clone())),
                    ("state", Value::Str("queued".to_string())),
                ]),
            )
        }
        Err(r) => reject(&r),
    }
}

/// `{"id": .., "state": .., <extra>}` — the `/requests/{id}` document.
fn request_state(id: u64, state: &str, extra: Option<(&str, Value)>) -> HttpResponse {
    let mut pairs = vec![("id", u64v(id)), ("state", Value::Str(state.to_string()))];
    if let Some(p) = extra {
        pairs.push(p);
    }
    HttpResponse::json(200, obj(pairs))
}

/// `GET /requests/{id}`: poll a submitted request. Finished requests
/// are one-shot — the first poll that observes completion consumes the
/// result, and later polls get `404`.
fn poll_request(st: &mut ControlState, id: &str) -> HttpResponse {
    if st.svc.is_none() {
        return closed();
    }
    let Ok(id) = id.parse::<u64>() else {
        return err_json(400, &format!("request id '{id}' is not an integer"));
    };
    let outcome = st.pending.get(&id).map(|rx| rx.try_recv());
    match outcome {
        None => err_json(404, &format!("unknown or already-consumed request id {id}")),
        Some(Err(TryRecvError::Empty)) => request_state(id, "pending", None),
        Some(Ok(Ok(out))) => {
            st.pending.remove(&id);
            request_state(id, "done", Some(("output_len", num(out.len()))))
        }
        Some(Ok(Err(e))) => {
            st.pending.remove(&id);
            request_state(id, "failed", Some(("error", Value::Str(e.to_string()))))
        }
        Some(Err(TryRecvError::Disconnected)) => {
            st.pending.remove(&id);
            let cause = Value::Str("response channel dropped".to_string());
            request_state(id, "failed", Some(("error", cause)))
        }
    }
}

/// `DELETE /requests/{id}`: purge a queued request. Only requests still
/// waiting in a queue can be cancelled — dispatched, finished, and
/// unknown ids report `404`.
fn cancel_request(st: &mut ControlState, id: &str) -> HttpResponse {
    let ControlState { svc, pending } = st;
    let Some(svc) = svc.as_ref() else {
        return closed();
    };
    let Ok(id) = id.parse::<u64>() else {
        return err_json(400, &format!("request id '{id}' is not an integer"));
    };
    if svc.cancel(id) {
        pending.remove(&id);
        let body = obj(vec![("id", u64v(id)), ("cancelled", Value::Bool(true))]);
        HttpResponse::json(200, body)
    } else {
        let cause = format!("request {id} is not queued (unknown, dispatched, or finished)");
        err_json(404, &cause)
    }
}

/// `POST /plan/apply`: body is a [`PlanDiff`] wire document. Decode
/// errors are `400`; a diff the live service refuses (semantic apply
/// failure) is `409` and leaves the service untouched. The success body
/// is exactly [`crate::coordinator::ApplyReport::to_json`] — bitwise
/// identical to a direct in-process [`IngestService::apply`] call.
fn apply_diff(st: &mut ControlState, body: &str) -> HttpResponse {
    let Some(svc) = st.svc.as_mut() else {
        return closed();
    };
    let v = match json::parse(body) {
        Ok(v) => v,
        Err(e) => return err_json(400, &format!("bad JSON body: {e}")),
    };
    let diff = match PlanDiff::from_wire_json(&v) {
        Ok(d) => d,
        Err(e) => return err_json(400, &e.to_string()),
    };
    match svc.apply(&diff) {
        Ok(report) => HttpResponse {
            status: 200,
            body: report.to_json().to_pretty(),
        },
        Err(e) => err_json(409, &e.to_string()),
    }
}

/// `POST /replan`: body is a [`FaultPlan`]. The planner re-plans the
/// incumbent on the fault's surviving board (every regime enumerated,
/// same split granularity) and the resulting diff is applied live. The
/// response carries the shed report, the replan phase, and the same
/// [`crate::coordinator::ApplyReport`] document `POST /plan/apply`
/// returns; an infeasible failover (every tenant shed) is `409`.
fn replan(st: &mut ControlState, body: &str) -> HttpResponse {
    let Some(svc) = st.svc.as_mut() else {
        return closed();
    };
    let v = match json::parse(body) {
        Ok(v) => v,
        Err(e) => return err_json(400, &format!("bad JSON body: {e}")),
    };
    let fault = match FaultPlan::from_json(&v) {
        Ok(f) => f,
        Err(e) => return err_json(400, &e.to_string()),
    };
    let incumbent: DeploymentPlan = svc.plan().clone();
    let outcome = match Planner::on(incumbent.board.clone())
        .steps(incumbent.steps)
        .schedule(ScheduleMode::Auto)
        .replan(&incumbent, &fault)
    {
        Ok(o) => o,
        Err(e) => return err_json(409, &e.to_string()),
    };
    let shed: Vec<Value> = outcome.shed.iter().map(shed_to_json).collect();
    let phase = Value::Str(outcome.phase.label().to_string());
    let Some(diff) = outcome.diff else {
        let cause = "no feasible failover plan on the surviving board — every tenant shed";
        return HttpResponse::json(
            409,
            obj(vec![
                ("error", Value::Str(cause.to_string())),
                ("phase", phase),
                ("shed", Value::Arr(shed)),
            ]),
        );
    };
    match svc.apply(&diff) {
        Ok(report) => HttpResponse::json(
            200,
            obj(vec![
                ("replanned", Value::Bool(true)),
                ("phase", phase),
                ("shed", Value::Arr(shed)),
                ("applied", report.to_json()),
            ]),
        ),
        Err(e) => err_json(409, &e.to_string()),
    }
}

/// `POST /replay`: body is a [`TraceSpec`]. Runs the deterministic
/// planned-timeline replay ([`serve_trace`]) against the active plan —
/// pure seeded arithmetic, so the response is byte-identical for the
/// same spec on any machine — and returns the serve report. Live
/// queues and histograms are not touched.
fn replay(st: &ControlState, body: &str) -> HttpResponse {
    let Some(svc) = st.svc.as_ref() else {
        return closed();
    };
    let v = match json::parse(body) {
        Ok(v) => v,
        Err(e) => return err_json(400, &format!("bad JSON body: {e}")),
    };
    let spec = match TraceSpec::from_json(&v) {
        Ok(s) => s,
        Err(e) => return err_json(400, &e.to_string()),
    };
    match serve_trace(svc.plan(), &spec) {
        Ok(report) => HttpResponse {
            status: 200,
            body: report.to_json().to_pretty(),
        },
        Err(e) => err_json(400, &e.to_string()),
    }
}

// ---------------------------------------------------------------------------
// HTTP/1.1 framing: the only code that touches bytes
// ---------------------------------------------------------------------------

/// Parse one HTTP/1.x request from a buffered reader: request line,
/// headers (only `Content-Length` is interpreted), then exactly that
/// many body bytes. Rejects non-HTTP preambles, oversized bodies, and
/// non-UTF-8 payloads with the real cause.
pub fn read_request<R: BufRead>(r: &mut R) -> crate::Result<HttpRequest> {
    let mut line = String::new();
    let n = r.read_line(&mut line)?;
    anyhow::ensure!(n > 0, "empty request");
    let mut parts = line.split_whitespace();
    let method = parts
        .next()
        .ok_or_else(|| anyhow::anyhow!("malformed request line"))?
        .to_string();
    let path = parts
        .next()
        .ok_or_else(|| anyhow::anyhow!("request line names no path"))?
        .to_string();
    let version = parts
        .next()
        .ok_or_else(|| anyhow::anyhow!("request line names no protocol version"))?;
    anyhow::ensure!(version.starts_with("HTTP/1."), "unsupported protocol '{version}'");
    let mut content_len = 0usize;
    loop {
        let mut header = String::new();
        let n = r.read_line(&mut header)?;
        anyhow::ensure!(n > 0, "request ended inside headers");
        let header = header.trim_end();
        if header.is_empty() {
            break;
        }
        if let Some((key, value)) = header.split_once(':') {
            if key.eq_ignore_ascii_case("content-length") {
                content_len = value
                    .trim()
                    .parse()
                    .map_err(|_| anyhow::anyhow!("bad Content-Length '{}'", value.trim()))?;
            }
        }
    }
    anyhow::ensure!(
        content_len <= MAX_BODY_BYTES,
        "request body of {content_len} bytes exceeds the {MAX_BODY_BYTES}-byte cap"
    );
    let mut body = vec![0u8; content_len];
    r.read_exact(&mut body)?;
    let body = String::from_utf8(body).map_err(|_| anyhow::anyhow!("body is not UTF-8"))?;
    Ok(HttpRequest { method, path, body })
}

/// Write one response with the minimal framing headers (JSON content
/// type, explicit length, `Connection: close` — one request per
/// connection keeps the adapter stateless).
pub fn write_response<W: Write>(w: &mut W, resp: &HttpResponse) -> std::io::Result<()> {
    write!(
        w,
        "HTTP/1.1 {} {}\r\nContent-Type: application/json\r\nContent-Length: {}\r\n\
         Connection: close\r\n\r\n",
        resp.status,
        resp.reason(),
        resp.body.len()
    )?;
    w.write_all(resp.body.as_bytes())?;
    w.flush()
}

/// Handle one accepted connection: parse, route through the plane,
/// write the response, close. A parse failure answers `400` rather
/// than dropping the connection. After serving the request that shut
/// the plane down, pokes the listener once so the accept loop observes
/// the flag and exits.
fn handle_connection(plane: &ControlPlane, mut stream: TcpStream, wake: SocketAddr) {
    let _ = stream.set_read_timeout(Some(IO_TIMEOUT));
    let _ = stream.set_write_timeout(Some(IO_TIMEOUT));
    let Ok(read_half) = stream.try_clone() else {
        return;
    };
    let mut reader = BufReader::new(read_half);
    let resp = match read_request(&mut reader) {
        Ok(req) => plane.handle(&req),
        Err(e) => err_json(400, &format!("bad request: {e}")),
    };
    let _ = write_response(&mut stream, &resp);
    let _ = stream.shutdown(std::net::Shutdown::Both);
    if plane.is_shut_down() {
        let _ = TcpStream::connect(wake);
    }
}

/// Run the TCP adapter until the plane shuts down: a fixed pool of
/// scoped worker threads drains an accept queue, and every connection
/// serves exactly one request. Returns after `POST /shutdown` has been
/// served and all in-flight handlers finished (dropping the queue
/// joins the pool — graceful drain, no connection is abandoned
/// mid-response).
pub fn serve(plane: &ControlPlane, listener: TcpListener) -> crate::Result<()> {
    let wake = listener.local_addr()?;
    let (tx, rx) = mpsc::channel::<TcpStream>();
    let rx = Mutex::new(rx);
    std::thread::scope(|s| {
        for _ in 0..CONTROL_WORKERS {
            let rx = &rx;
            s.spawn(move || loop {
                let next = rx.lock().unwrap().recv();
                match next {
                    Ok(stream) => handle_connection(plane, stream, wake),
                    Err(_) => break,
                }
            });
        }
        for stream in listener.incoming() {
            if plane.is_shut_down() {
                break;
            }
            if let Ok(st) = stream {
                let _ = tx.send(st);
            }
        }
        drop(tx);
    });
    Ok(())
}

/// Minimal HTTP client for the `flexipipe ctl` subcommand: one request
/// per connection, returns `(status, body)`. Depends only on
/// [`TcpStream`] — the same zero-crate policy as the server side.
pub fn http_request(
    addr: &str,
    method: &str,
    path: &str,
    body: Option<&str>,
) -> crate::Result<(u16, String)> {
    let mut stream =
        TcpStream::connect(addr).map_err(|e| anyhow::anyhow!("connecting to {addr}: {e}"))?;
    stream.set_read_timeout(Some(IO_TIMEOUT))?;
    stream.set_write_timeout(Some(IO_TIMEOUT))?;
    let body = body.unwrap_or("");
    write!(
        stream,
        "{method} {path} HTTP/1.1\r\nHost: {addr}\r\nConnection: close\r\n\
         Content-Length: {}\r\n\r\n{body}",
        body.len()
    )?;
    stream.flush()?;
    let mut raw = Vec::new();
    let mut reader = BufReader::new(stream);
    reader.read_to_end(&mut raw)?;
    let text = String::from_utf8(raw)
        .map_err(|_| anyhow::anyhow!("response from {addr} is not UTF-8"))?;
    let (head, payload) = text
        .split_once("\r\n\r\n")
        .ok_or_else(|| anyhow::anyhow!("malformed response from {addr} (no header end)"))?;
    let status: u16 = head
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .ok_or_else(|| anyhow::anyhow!("malformed status line in response from {addr}"))?;
    Ok((status, payload.to_string()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::board::zedboard;
    use crate::coordinator::BatchPolicy;
    use crate::ingest::{ArrivalProcess, IngestPolicy, TenantTrace};
    use crate::model::zoo;
    use crate::plan::Workload;
    use crate::quant::QuantMode;

    fn test_plan() -> DeploymentPlan {
        let w = Workload::new(QuantMode::W8A8).tenant(zoo::tinycnn()).tenant(zoo::lenet());
        let set = Planner::on(zedboard()).steps(8).plan(&w).unwrap();
        set.plans[set.best].clone()
    }

    fn ingest(plan: &DeploymentPlan) -> IngestService {
        IngestService::start(plan, BatchPolicy::default(), IngestPolicy::default()).unwrap()
    }

    fn plane() -> ControlPlane {
        ControlPlane::new(ingest(&test_plan()))
    }

    fn call(p: &ControlPlane, method: &str, path: &str, body: &str) -> HttpResponse {
        p.handle(&HttpRequest {
            method: method.to_string(),
            path: path.to_string(),
            body: body.to_string(),
        })
    }

    fn get(p: &ControlPlane, path: &str) -> HttpResponse {
        call(p, "GET", path, "")
    }

    fn post(p: &ControlPlane, path: &str, body: &str) -> HttpResponse {
        call(p, "POST", path, body)
    }

    #[test]
    fn http_requests_parse_and_reject_garbage() {
        use std::io::Cursor;
        let raw = b"POST /plan/apply HTTP/1.1\r\nHost: x\r\nContent-Length: 4\r\n\r\n{\"a\"";
        let req = read_request(&mut Cursor::new(&raw[..])).unwrap();
        assert_eq!(req.method, "POST");
        assert_eq!(req.path, "/plan/apply");
        assert_eq!(req.body, "{\"a\"");

        // No Content-Length means no body.
        let raw = b"GET /health HTTP/1.1\r\nHost: x\r\n\r\n";
        let req = read_request(&mut Cursor::new(&raw[..])).unwrap();
        assert_eq!(req.method, "GET");
        assert!(req.body.is_empty());

        // Non-HTTP preambles are refused, not misrouted.
        assert!(read_request(&mut Cursor::new(&b"nonsense\r\n\r\n"[..])).is_err());
        assert!(read_request(&mut Cursor::new(&b""[..])).is_err());
        let raw = b"GET /x SMTP/1.0\r\n\r\n";
        let err = read_request(&mut Cursor::new(&raw[..])).unwrap_err();
        assert!(err.to_string().contains("SMTP/1.0"), "{err}");

        // The writer frames status, length, and the exact body bytes.
        let mut out = Vec::new();
        let resp = HttpResponse {
            status: 200,
            body: "{}".to_string(),
        };
        write_response(&mut out, &resp).unwrap();
        let text = String::from_utf8(out).unwrap();
        assert!(text.starts_with("HTTP/1.1 200 OK\r\n"), "{text}");
        assert!(text.contains("Content-Length: 2\r\n"), "{text}");
        assert!(text.ends_with("\r\n\r\n{}"), "{text}");
    }

    #[test]
    fn router_distinguishes_unknown_routes_from_wrong_methods() {
        let p = plane();
        let missing = get(&p, "/nope");
        assert_eq!(missing.status, 404);
        assert!(missing.body.contains("error"), "{}", missing.body);
        // A real path with the wrong verb is 405, not 404.
        assert_eq!(get(&p, "/plan/apply").status, 405);
        assert_eq!(call(&p, "DELETE", "/health", "").status, 405);
        assert_eq!(post(&p, "/queues", "").status, 405);
        // Query strings are ignored by the router.
        assert_eq!(get(&p, "/health?verbose=1").status, 200);
    }

    #[test]
    fn health_reports_every_tenant() {
        let p = plane();
        let resp = get(&p, "/health");
        assert_eq!(resp.status, 200);
        let v = json::parse(&resp.body).unwrap();
        let tenants = v.req("tenants").unwrap().as_arr().unwrap();
        assert_eq!(tenants.len(), 2);
        assert_eq!(tenants[0].str_field("tenant").unwrap(), "tinycnn");
        assert_eq!(tenants[1].str_field("tenant").unwrap(), "lenet");
        for t in tenants {
            assert_eq!(t.str_field("health").unwrap(), "healthy");
            assert_eq!(t.usize_field("requests").unwrap(), 0);
        }
    }

    #[test]
    fn queue_snapshots_are_byte_identical_across_fresh_services() {
        // The encoding side of the determinism story: two services on
        // the same plan answer /queues with the same bytes before any
        // wall-clock-dependent traffic has run.
        let (a, b) = (plane(), plane());
        let (qa, qb) = (get(&a, "/queues"), get(&b, "/queues"));
        assert_eq!(qa.status, 200);
        assert_eq!(qa.body, qb.body);
        let v = json::parse(&qa.body).unwrap();
        let queues = v.req("queues").unwrap().as_arr().unwrap();
        assert_eq!(queues.len(), 2);
        for q in queues {
            assert_eq!(q.usize_field("depth").unwrap(), 0);
            assert_eq!(q.usize_field("admitted").unwrap(), 0);
            assert!(q.usize_field("capacity").unwrap() >= 1);
        }
    }

    #[test]
    fn plan_endpoint_round_trips_the_active_plan() {
        let plan = test_plan();
        let p = ControlPlane::new(ingest(&plan));
        let resp = get(&p, "/plan");
        assert_eq!(resp.status, 200);
        assert_eq!(resp.body, plan.to_json().to_pretty());
    }

    #[test]
    fn submit_poll_and_consume_a_request() {
        let p = plane();
        let resp = post(&p, "/submit", r#"{"tenant": "tinycnn"}"#);
        assert_eq!(resp.status, 200, "{}", resp.body);
        let v = json::parse(&resp.body).unwrap();
        assert_eq!(v.str_field("state").unwrap(), "queued");
        let id = v.usize_field("id").unwrap();
        let mut done = false;
        for _ in 0..2000 {
            let r = get(&p, &format!("/requests/{id}"));
            assert_eq!(r.status, 200, "{}", r.body);
            let v = json::parse(&r.body).unwrap();
            match v.str_field("state").unwrap() {
                "done" => {
                    assert!(v.usize_field("output_len").unwrap() > 0);
                    done = true;
                    break;
                }
                "failed" => panic!("request failed: {}", r.body),
                _ => std::thread::sleep(Duration::from_millis(1)),
            }
        }
        assert!(done, "request never completed");
        // The result was consumed by the poll above: one-shot.
        assert_eq!(get(&p, &format!("/requests/{id}")).status, 404);
        assert_eq!(get(&p, "/requests/notanumber").status, 400);
    }

    #[test]
    fn zero_relative_deadlines_always_expire() {
        // The acceptance property, through the HTTP surface: a deadline
        // that resolves at-or-before the admission instant is rejected
        // 408/DeadlineExpired every time — never served, never queued,
        // and never misreported as queue-full.
        let p = plane();
        for _ in 0..20 {
            let r = post(&p, "/submit", r#"{"tenant": 0, "deadline_ms": 0}"#);
            assert_eq!(r.status, 408, "{}", r.body);
            let v = json::parse(&r.body).unwrap();
            assert_eq!(v.str_field("reason").unwrap(), "deadline-expired");
        }
        let q = json::parse(&get(&p, "/queues").body).unwrap();
        let t0 = &q.req("queues").unwrap().as_arr().unwrap()[0];
        assert_eq!(t0.usize_field("expired").unwrap(), 20);
        assert_eq!(t0.usize_field("admitted").unwrap(), 0);
        assert_eq!(t0.usize_field("completed").unwrap(), 0);
    }

    #[test]
    fn submit_validates_tenants_frames_and_knobs() {
        let p = plane();
        assert_eq!(post(&p, "/submit", r#"{"tenant": "nope"}"#).status, 404);
        assert_eq!(post(&p, "/submit", r#"{"tenant": 9}"#).status, 404);
        assert_eq!(post(&p, "/submit", "not json").status, 400);
        assert_eq!(post(&p, "/submit", r#"{}"#).status, 400);
        let r = post(&p, "/submit", r#"{"tenant": "tinycnn", "frame": [1, 2]}"#);
        assert_eq!(r.status, 400);
        assert!(r.body.contains("must hold"), "{}", r.body);
        let pr = r#"{"tenant": "tinycnn", "priority": 900}"#;
        assert_eq!(post(&p, "/submit", pr).status, 400);
        let dl = r#"{"tenant": "tinycnn", "deadline_ms": -1}"#;
        assert_eq!(post(&p, "/submit", dl).status, 400);
    }

    #[test]
    fn cancel_purges_queued_requests_only() {
        // A long link latency pins the first request in flight, so the
        // second is deterministically still queued when the DELETE lands.
        let plan = test_plan();
        let batch = BatchPolicy {
            link_latency: Duration::from_millis(200),
            ..BatchPolicy::default()
        };
        let policy = IngestPolicy {
            queue_capacity: 4,
            ..IngestPolicy::default()
        };
        let p = ControlPlane::new(IngestService::start(&plan, batch, policy).unwrap());
        let r1 = post(&p, "/submit", r#"{"tenant": "tinycnn"}"#);
        let r2 = post(&p, "/submit", r#"{"tenant": "tinycnn"}"#);
        let id1 = json::parse(&r1.body).unwrap().usize_field("id").unwrap();
        let id2 = json::parse(&r2.body).unwrap().usize_field("id").unwrap();
        let del = call(&p, "DELETE", &format!("/requests/{id2}"), "");
        assert_eq!(del.status, 200, "{}", del.body);
        let v = json::parse(&del.body).unwrap();
        assert_eq!(v.req("cancelled").unwrap().as_bool(), Some(true));
        // The receiver map entry went with it.
        assert_eq!(get(&p, &format!("/requests/{id2}")).status, 404);
        assert_eq!(call(&p, "DELETE", "/requests/999999", "").status, 404);
        let q = json::parse(&get(&p, "/queues").body).unwrap();
        let t0 = &q.req("queues").unwrap().as_arr().unwrap()[0];
        assert_eq!(t0.usize_field("cancelled").unwrap(), 1);
        // The survivor still completes.
        let mut done = false;
        for _ in 0..3000 {
            let r = get(&p, &format!("/requests/{id1}"));
            let v = json::parse(&r.body).unwrap();
            match v.get("state").and_then(|s| s.as_str()) {
                Some("done") => {
                    done = true;
                    break;
                }
                Some("failed") => panic!("survivor failed: {}", r.body),
                _ => std::thread::sleep(Duration::from_millis(1)),
            }
        }
        assert!(done, "surviving request never completed");
    }

    #[test]
    fn apply_over_the_wire_matches_the_direct_call() {
        // The acceptance criterion: POST /plan/apply returns an
        // ApplyReport bitwise identical to a direct in-process apply of
        // the same diff, and the active plan lands on the target bytes.
        let w = Workload::new(QuantMode::W8A8).tenant(zoo::tinycnn()).tenant(zoo::lenet());
        let set = Planner::on(zedboard()).steps(8).plan(&w).unwrap();
        let a = set.plans[set.best].clone();
        let b = set
            .plans
            .iter()
            .find(|p| p.tenants[0].dsp_parts != a.tenants[0].dsp_parts)
            .expect("an 8-step spatial search holds more than one split")
            .clone();
        let diff = a.diff(&b).unwrap();

        let mut direct = ingest(&a);
        let direct_report = direct.apply(&diff).unwrap().to_json().to_pretty();
        let _ = direct.shutdown();

        let p = ControlPlane::new(ingest(&a));
        let resp = post(&p, "/plan/apply", &diff.to_wire_json().to_pretty());
        assert_eq!(resp.status, 200, "{}", resp.body);
        assert_eq!(resp.body, direct_report);
        assert_eq!(get(&p, "/plan").body, b.to_json().to_pretty());

        // Decode failures are 400 and leave the plan untouched.
        assert_eq!(post(&p, "/plan/apply", "{}").status, 400);
        assert_eq!(post(&p, "/plan/apply", "junk").status, 400);
        assert_eq!(get(&p, "/plan").body, b.to_json().to_pretty());
    }

    #[test]
    fn replan_with_no_faults_keeps_every_tenant() {
        let p = plane();
        let body = FaultPlan::none().to_json().to_pretty();
        let resp = post(&p, "/replan", &body);
        assert_eq!(resp.status, 200, "{}", resp.body);
        let v = json::parse(&resp.body).unwrap();
        assert_eq!(v.req("replanned").unwrap().as_bool(), Some(true));
        assert_eq!(v.str_field("phase").unwrap(), "warm-start");
        assert!(v.req("shed").unwrap().as_arr().unwrap().is_empty());
        let applied = v.req("applied").unwrap();
        let survivors = applied.req("kept").unwrap().as_arr().unwrap().len()
            + applied.req("restarted").unwrap().as_arr().unwrap().len();
        assert_eq!(survivors, 2);
        assert!(applied.req("removed").unwrap().as_arr().unwrap().is_empty());
        // The service still answers for both tenants.
        let h = json::parse(&get(&p, "/health").body).unwrap();
        assert_eq!(h.req("tenants").unwrap().as_arr().unwrap().len(), 2);
        // A bad fault document is a 400 with the real cause.
        let bad = post(&p, "/replan", r#"{"version": 9, "seed": 0}"#);
        assert_eq!(bad.status, 400);
        assert!(bad.body.contains("version 9"), "{}", bad.body);
    }

    #[test]
    fn replay_reports_are_deterministic_and_leave_live_state_alone() {
        let (p1, p2) = (plane(), plane());
        let spec = TraceSpec {
            seed: 7,
            duration_s: 1.0,
            queue_capacity: 0,
            tenants: vec![
                TenantTrace {
                    tenant: "tinycnn".to_string(),
                    process: ArrivalProcess::Poisson { rate_fps: 40.0 },
                },
                TenantTrace {
                    tenant: "lenet".to_string(),
                    process: ArrivalProcess::ClosedLoop {
                        clients: 2,
                        think_time_s: 0.05,
                    },
                },
            ],
        };
        let spec = spec.to_json().to_pretty();
        let (r1, r2) = (post(&p1, "/replay", &spec), post(&p2, "/replay", &spec));
        assert_eq!(r1.status, 200, "{}", r1.body);
        assert_eq!(r1.body, r2.body, "replay must be byte-deterministic");
        // The replay is model-side only: live introspection still reads
        // as two untouched services, byte for byte.
        assert_eq!(get(&p1, "/queues").body, get(&p2, "/queues").body);
        assert_eq!(get(&p1, "/histograms").body, get(&p2, "/histograms").body);
        // Unknown tenants in the spec are a 400.
        let bad = spec.replace("tinycnn", "ghost");
        assert_eq!(post(&p1, "/replay", &bad).status, 400);
    }

    #[test]
    fn histograms_cover_tenants_and_reject_unknown_names() {
        let p = plane();
        let resp = post(&p, "/submit", r#"{"tenant": "tinycnn"}"#);
        let id = json::parse(&resp.body).unwrap().usize_field("id").unwrap();
        for _ in 0..2000 {
            let r = get(&p, &format!("/requests/{id}"));
            if r.status == 404 || r.body.contains("\"done\"") {
                break;
            }
            std::thread::sleep(Duration::from_millis(1));
        }
        let resp = get(&p, "/histograms/tinycnn");
        assert_eq!(resp.status, 200);
        let v = json::parse(&resp.body).unwrap();
        assert!(v.usize_field("count").unwrap() >= 1);
        let p50 = v.usize_field("p50_us").unwrap();
        let p99 = v.usize_field("p99_us").unwrap();
        assert!(p50 <= p99 && p99 <= v.usize_field("max_us").unwrap());
        assert_eq!(get(&p, "/histograms/ghost").status, 404);
        let all = json::parse(&get(&p, "/histograms").body).unwrap();
        assert_eq!(all.req("tenants").unwrap().as_arr().unwrap().len(), 2);
    }

    #[test]
    fn shutdown_drains_and_closes_every_endpoint() {
        let p = plane();
        assert!(!p.is_shut_down());
        let resp = post(&p, "/shutdown", "");
        assert_eq!(resp.status, 200, "{}", resp.body);
        assert!(p.is_shut_down());
        let v = json::parse(&resp.body).unwrap();
        assert_eq!(v.req("shut_down").unwrap().as_bool(), Some(true));
        assert_eq!(v.req("queues").unwrap().as_arr().unwrap().len(), 2);
        for (method, path) in [
            ("GET", "/health"),
            ("GET", "/queues"),
            ("GET", "/plan"),
            ("GET", "/histograms"),
            ("POST", "/submit"),
            ("POST", "/replan"),
            ("POST", "/shutdown"),
        ] {
            assert_eq!(call(&p, method, path, "").status, 503, "{method} {path}");
        }
    }
}
