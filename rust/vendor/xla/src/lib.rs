//! Stub of the `xla` (xla_extension) bindings used by `flexipipe::runtime`.
//!
//! The offline vendor set ships no PJRT plugin, so this crate mirrors the
//! exact API surface the runtime calls and fails fast at client
//! construction with an instructive error. Everything downstream of
//! [`PjRtClient::cpu`] is therefore unreachable in an offline build; the
//! runtime-dependent tests and benches detect the missing artifact
//! directory (or this error) and skip. Swapping the `xla` path dependency
//! for the real bindings restores execution without touching `runtime/`.

use std::fmt;

/// Stub error: always "PJRT unavailable".
pub struct XlaError(String);

impl XlaError {
    fn unavailable() -> Self {
        XlaError(
            "PJRT unavailable: flexipipe was built with the in-tree `xla` stub \
             (offline vendor set). Point Cargo.toml's `xla` dependency at the \
             real xla_extension bindings to execute HLO artifacts."
                .to_string(),
        )
    }
}

impl fmt::Debug for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl fmt::Display for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for XlaError {}

/// Element types the runtime names (S8 only today).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    S8,
}

/// Parsed HLO module (stub).
pub struct HloModuleProto;

impl HloModuleProto {
    /// Parse an HLO text file (stub: always unavailable).
    pub fn from_text_file(_path: &str) -> Result<Self, XlaError> {
        Err(XlaError::unavailable())
    }
}

/// XLA computation wrapper (stub).
pub struct XlaComputation;

impl XlaComputation {
    /// Wrap a parsed module.
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        XlaComputation
    }
}

/// Host literal (stub).
pub struct Literal;

impl Literal {
    /// Build a literal from a shape and raw bytes (stub).
    pub fn create_from_shape_and_untyped_data(
        _ty: ElementType,
        _shape: &[usize],
        _data: &[u8],
    ) -> Result<Self, XlaError> {
        Err(XlaError::unavailable())
    }

    /// Unwrap a 1-tuple result (stub).
    pub fn to_tuple1(&self) -> Result<Literal, XlaError> {
        Err(XlaError::unavailable())
    }

    /// Copy out as a typed vector (stub).
    pub fn to_vec<T>(&self) -> Result<Vec<T>, XlaError> {
        Err(XlaError::unavailable())
    }
}

/// Device buffer handle (stub).
pub struct PjRtBuffer;

impl PjRtBuffer {
    /// Fetch the buffer back to a host literal (stub).
    pub fn to_literal_sync(&self) -> Result<Literal, XlaError> {
        Err(XlaError::unavailable())
    }
}

/// Compiled executable handle (stub).
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    /// Execute on device buffers (stub).
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>, XlaError> {
        Err(XlaError::unavailable())
    }
}

/// PJRT client handle (stub: construction always fails).
pub struct PjRtClient;

impl PjRtClient {
    /// Open the CPU PJRT plugin (stub: always unavailable).
    pub fn cpu() -> Result<Self, XlaError> {
        Err(XlaError::unavailable())
    }

    /// Platform name (unreachable in the stub — construction fails).
    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    /// Compile a computation (unreachable in the stub).
    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, XlaError> {
        Err(XlaError::unavailable())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_reports_unavailable() {
        let err = PjRtClient::cpu().err().expect("stub must fail");
        assert!(format!("{err:?}").contains("PJRT unavailable"));
    }
}
