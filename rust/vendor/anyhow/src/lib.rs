//! Minimal in-tree substitute for the `anyhow` crate.
//!
//! The offline vendor set has no crates.io access, so this package provides
//! the exact subset of anyhow's API the workspace uses: [`Error`],
//! [`Result`], and the [`anyhow!`], [`bail!`], [`ensure!`] macros. Like the
//! real crate, `Error` deliberately does **not** implement
//! `std::error::Error` so the blanket `From` conversion below stays
//! coherent. Replacing this with the real `anyhow` is a one-line change in
//! `Cargo.toml`.

use std::error::Error as StdError;
use std::fmt;

/// Boxed dynamic error with a display-oriented surface (anyhow-compatible).
pub struct Error(Box<dyn StdError + Send + Sync + 'static>);

/// Ad-hoc message error backing [`Error::msg`].
struct MessageError(String);

impl fmt::Display for MessageError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl fmt::Debug for MessageError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl StdError for MessageError {}

impl Error {
    /// Construct from a display-able message.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error(Box::new(MessageError(message.to_string())))
    }

    /// Construct from a concrete error value.
    pub fn new<E: StdError + Send + Sync + 'static>(error: E) -> Self {
        Error(Box::new(error))
    }

    /// Downcast reference (parity with anyhow's API).
    pub fn downcast_ref<E: StdError + 'static>(&self) -> Option<&E> {
        self.0.downcast_ref::<E>()
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        fmt::Display::fmt(&self.0, f)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // anyhow prints the message, then the cause chain.
        write!(f, "{}", self.0)?;
        let mut source = self.0.source();
        if source.is_some() {
            write!(f, "\n\nCaused by:")?;
        }
        while let Some(cause) = source {
            write!(f, "\n    {cause}")?;
            source = cause.source();
        }
        Ok(())
    }
}

impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(error: E) -> Self {
        Error(Box::new(error))
    }
}

/// `anyhow::Result<T>` — the crate-wide fallible type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Construct an [`Error`] from a format string or error value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg(format!("{}", $err))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an [`Error`] when a condition fails.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: {}", stringify!($cond));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn from_std_error_and_display() {
        let e: Error = io_err().into();
        assert!(e.to_string().contains("gone"));
        assert!(e.downcast_ref::<std::io::Error>().is_some());
    }

    #[test]
    fn macros_build_messages() {
        let name = "x";
        let e = anyhow!("missing field '{name}'");
        assert_eq!(e.to_string(), "missing field 'x'");
        let e = anyhow!("{} of {}", 1, 2);
        assert_eq!(e.to_string(), "1 of 2");
    }

    #[test]
    fn bail_and_ensure() {
        fn f(ok: bool) -> Result<u32> {
            ensure!(ok, "wanted {}", true);
            if !ok {
                bail!("unreachable");
            }
            Ok(7)
        }
        assert_eq!(f(true).unwrap(), 7);
        assert!(f(false).is_err());
    }
}
