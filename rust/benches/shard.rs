//! Bench: the multi-tenant sharder and the shared-DDR multi-pipeline DES,
//! for the §Perf trajectory.
//!
//! - full split-space search (vgg16 + alexnet on a ZC706 at 8-bit): the
//!   factorized per-tenant table + warm-started staircases are what keep
//!   this in allocator-call territory instead of compositions × tenants,
//! - single-tenant sharder overhead vs the plain allocator (should be ≈1×:
//!   one split exists and it is the whole board),
//! - the multi-pipeline DES vs two independent single-pipeline runs.
//!
//! Emits machine-readable `BENCH_shard.json` at the repository root,
//! alongside `BENCH_hotpath.json`, so future PRs can track the trajectory.

use flexipipe::alloc::flex::FlexAllocator;
use flexipipe::alloc::Allocator;
use flexipipe::board::zc706;
use flexipipe::model::zoo;
use flexipipe::quant::QuantMode;
use flexipipe::shard::{Sharder, Tenant};
use flexipipe::sim;
use flexipipe::util::bench::BenchOpts;
use flexipipe::util::json::{obj, Value};
use std::path::Path;

fn main() {
    let opts = BenchOpts::parse(
        2.0,
        Path::new(env!("CARGO_MANIFEST_DIR")).join("../BENCH_shard.json"),
    );
    let mut b = opts.bench();
    let mut out: Vec<(&str, Value)> = Vec::new();

    // Two-tenant split search: the tentpole workload.
    let two_tenant = || Sharder {
        steps: 8,
        ..Sharder::new(
            zc706(),
            vec![
                Tenant::new(zoo::vgg16(), QuantMode::W8A8),
                Tenant::new(zoo::alexnet(), QuantMode::W8A8),
            ],
        )
    };
    let s = b
        .bench("shard/vgg16+alexnet/8steps", || two_tenant().search().unwrap())
        .clone();
    let search_ms = s.mean.as_secs_f64() * 1e3;
    let result = two_tenant().search().unwrap();
    println!(
        "  -> {} feasible plans, {} on the frontier",
        result.plans.len(),
        result.frontier.len()
    );
    out.push(("shard_search_ms", Value::Num(search_ms)));
    out.push(("shard_plans", Value::Num(result.plans.len() as f64)));
    out.push(("shard_frontier", Value::Num(result.frontier.len() as f64)));

    // Same search with branch-and-bound pruning: identical frontier, fewer
    // lattice nodes expanded.
    let pruned_sharder = || Sharder {
        prune: true,
        ..two_tenant()
    };
    let s = b
        .bench("shard/vgg16+alexnet/8steps/pruned", || {
            pruned_sharder().search().unwrap()
        })
        .clone();
    let pruned_ms = s.mean.as_secs_f64() * 1e3;
    let pruned = pruned_sharder().search().unwrap();
    assert_eq!(
        pruned.frontier.iter().map(|&i| &pruned.plans[i].fps).collect::<Vec<_>>(),
        result.frontier.iter().map(|&i| &result.plans[i].fps).collect::<Vec<_>>(),
        "pruned search must keep the frontier"
    );
    println!(
        "  -> pruned: {}/{} lattice nodes skipped, {} allocator runs ({:.2}x vs exhaustive)",
        pruned.stats.pruned_nodes,
        pruned.stats.lattice_nodes,
        pruned.stats.alloc_calls,
        search_ms / pruned_ms
    );
    out.push(("shard_search_pruned_ms", Value::Num(pruned_ms)));
    out.push(("shard_lattice_nodes", Value::Num(pruned.stats.lattice_nodes as f64)));
    out.push(("shard_pruned_nodes", Value::Num(pruned.stats.pruned_nodes as f64)));
    out.push(("shard_alloc_calls", Value::Num(pruned.stats.alloc_calls as f64)));

    // Single-tenant overhead: the sharder collapses to one plan.
    let s = b
        .bench("shard/alexnet-solo", || {
            Sharder::new(zc706(), vec![Tenant::new(zoo::alexnet(), QuantMode::W8A8)])
                .search()
                .unwrap()
        })
        .clone();
    let solo_shard = s.mean.as_secs_f64();
    let s = b
        .bench("alloc/alexnet (plain)", || {
            FlexAllocator::default()
                .allocate(&zoo::alexnet(), &zc706(), QuantMode::W8A8)
                .unwrap()
        })
        .clone();
    let solo_plain = s.mean.as_secs_f64();
    println!(
        "  -> single-tenant sharder overhead: {:.2}x the plain allocator",
        solo_shard / solo_plain
    );
    out.push(("shard_solo_overhead", Value::Num(solo_shard / solo_plain)));

    // Multi-pipeline DES: two co-resident tinycnn pipelines on one port.
    let board = zc706();
    let half = flexipipe::shard::sub_board(&board, 1, 1, 2);
    let a = FlexAllocator::default()
        .allocate(&zoo::tinycnn(), &half, QuantMode::W8A8)
        .unwrap();
    let s = b
        .bench("sim/multi 2x tinycnn/4frames", || {
            sim::engines::simulate_multi(&[&a, &a], &board, 4)
        })
        .clone();
    let multi_ms = s.mean.as_secs_f64() * 1e3;
    let s = b
        .bench("sim/solo 2x tinycnn/4frames", || {
            (sim::simulate(&a, 4), sim::simulate(&a, 4))
        })
        .clone();
    println!(
        "  -> shared-port overhead vs 2 independent runs: {:.2}x",
        multi_ms / (s.mean.as_secs_f64() * 1e3)
    );
    out.push(("sim_multi_2x_tinycnn_ms", Value::Num(multi_ms)));

    b.finish();

    opts.write(&obj(out).to_pretty());
}
