//! Bench: the ingestion layer's hot paths, for the §Perf trajectory.
//!
//! - seeded arrival generation (three processes, 60 s horizon),
//! - deterministic trace replay (`ingest::serve_trace`) of a Poisson
//!   workload against a temporal vgg16+alexnet plan,
//! - the log-bucketed latency histogram's record path,
//! - the slice gate (`ingest::slice_open`) the live dispatcher polls.
//!
//! Emits machine-readable `BENCH_ingest.json` at the repository root,
//! alongside `BENCH_timeshare.json` / `BENCH_shard.json`.

use flexipipe::board::zc706;
use flexipipe::ingest::{self, ArrivalProcess, LatencyHistogram, TenantTrace, TraceSpec};
use flexipipe::model::zoo;
use flexipipe::plan::{Planner, Workload};
use flexipipe::quant::QuantMode;
use flexipipe::shard::{Regime, ScheduleMode};
use flexipipe::util::bench::BenchOpts;
use flexipipe::util::json::{obj, Value};
use std::path::Path;

fn spec(duration_s: f64) -> TraceSpec {
    TraceSpec {
        seed: 0xFEED,
        duration_s,
        queue_capacity: 0,
        tenants: vec![
            TenantTrace {
                tenant: "vgg16".into(),
                process: ArrivalProcess::Diurnal {
                    base_fps: 0.5,
                    peak_fps: 1.8,
                    period_s: 5.0,
                },
            },
            TenantTrace {
                tenant: "alexnet".into(),
                process: ArrivalProcess::Poisson { rate_fps: 3.0 },
            },
        ],
    }
}

fn main() {
    let opts = BenchOpts::parse(
        2.0,
        Path::new(env!("CARGO_MANIFEST_DIR")).join("../BENCH_ingest.json"),
    );
    let mut b = opts.bench();
    let mut out: Vec<(&str, Value)> = Vec::new();

    // Arrival generation: three processes over a long horizon.
    let gen_spec = spec(60.0);
    let s = b
        .bench("ingest/arrivals 60s", || {
            gen_spec.arrivals(zc706().freq_hz).unwrap()
        })
        .clone();
    out.push(("arrivals_ms", Value::Num(s.mean.as_secs_f64() * 1e3)));
    let arr = gen_spec.arrivals(zc706().freq_hz).unwrap();
    println!("  -> {} + {} arrivals over 60 s", arr[0].len(), arr[1].len());

    // Deterministic replay against a real temporal plan.
    let workload = Workload::new(QuantMode::W8A8).tenant(zoo::vgg16()).tenant(zoo::alexnet());
    let set = Planner::on(zc706())
        .steps(8)
        .schedule(ScheduleMode::Temporal)
        .plan(&workload)
        .unwrap();
    let plan = set.plans[set.best_min].clone();
    assert!(matches!(plan.regime, Regime::Temporal(_)));
    let replay_spec = spec(20.0);
    let s = b
        .bench("ingest/serve_trace 20s", || {
            ingest::serve_trace(&plan, &replay_spec).unwrap()
        })
        .clone();
    out.push(("serve_trace_ms", Value::Num(s.mean.as_secs_f64() * 1e3)));
    let report = ingest::serve_trace(&plan, &replay_spec).unwrap();
    for t in &report.tenants {
        println!(
            "  -> {}: {} offered, {} admitted, p100 {} cycles (bound {:?})",
            t.net, t.offered, t.admitted, t.p100_cycles, t.worst_sojourn_cycles
        );
    }
    out.push((
        "replay_admitted",
        Value::Num(report.tenants.iter().map(|t| t.admitted as f64).sum()),
    ));

    // Histogram record path (the live dispatcher's per-completion cost).
    let samples: Vec<u64> = (0..100_000u64).map(|i| i.wrapping_mul(2654435761) >> 16).collect();
    let s = b
        .bench("ingest/hist record 100k", || {
            let mut h = LatencyHistogram::new();
            for &v in &samples {
                h.record(v);
            }
            h.quantile(99.0)
        })
        .clone();
    out.push(("hist_100k_ms", Value::Num(s.mean.as_secs_f64() * 1e3)));

    // Slice gate: what the dispatcher polls per tenant per loop.
    if let Regime::Temporal(info) = &plan.regime {
        let period = info.period_cycles.max(1);
        let s = b
            .bench("ingest/slice_open 10k", || {
                let mut open = 0u32;
                for i in 0..10_000u64 {
                    if ingest::slice_open(info, (i % 2) as usize, (i * 997) % period) {
                        open += 1;
                    }
                }
                open
            })
            .clone();
        out.push(("slice_open_10k_ms", Value::Num(s.mean.as_secs_f64() * 1e3)));
    }

    b.finish();

    opts.write(&obj(out).to_pretty());
}
