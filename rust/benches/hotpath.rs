//! Bench: hot paths of the allocator / simulator / search stack, for the
//! §Perf pass.
//!
//! - allocator end-to-end, optimized vs the preserved naive reference
//!   (`alloc::flex::naive`) — the PR-over-PR speedup trajectory,
//! - the DES simulator's event throughput (simulated cycles per
//!   wall-second), event-wheel vs naive full-rescan scheduler,
//! - the full design-space sweep (boards × paper nets × precisions),
//!   parallel + shared tables vs the serial naive loop,
//! - JSON manifest parse,
//! - PJRT execute latency per artifact batch (needs `make artifacts`;
//!   skipped gracefully when absent).
//!
//! Emits machine-readable `BENCH_hotpath.json` at the repository root so
//! future PRs can track the perf trajectory.

use flexipipe::alloc::flex::{naive, FlexAllocator};
use flexipipe::alloc::Allocator;
use flexipipe::board::{vc707, zc706, zcu102, zedboard};
use flexipipe::model::zoo;
use flexipipe::quant::QuantMode;
use flexipipe::runtime::{default_artifact_dir, Runtime};
use flexipipe::search::DesignSpace;
use flexipipe::sim;
use flexipipe::util::bench::BenchOpts;
use flexipipe::util::json::{self, obj, Value};
use std::path::Path;

fn main() {
    let opts = BenchOpts::parse(
        1.5,
        Path::new(env!("CARGO_MANIFEST_DIR")).join("../BENCH_hotpath.json"),
    );
    let mut b = opts.bench();
    let board = zc706();
    let mut out: Vec<(&str, Value)> = Vec::new();

    // Allocator: optimized vs naive reference.
    let vgg = zoo::vgg16();
    let s = b
        .bench("alloc/vgg16", || {
            FlexAllocator::default()
                .allocate(&vgg, &board, QuantMode::W16A16)
                .unwrap()
        })
        .clone();
    let fast_alloc = s.mean.as_secs_f64();
    let yolo = zoo::yolo();
    b.bench("alloc/yolo", || {
        FlexAllocator::default()
            .allocate(&yolo, &board, QuantMode::W16A16)
            .unwrap()
    });
    let s = b
        .bench("alloc/vgg16/naive", || {
            naive::allocate(&FlexAllocator::default(), &vgg, &board, QuantMode::W16A16).unwrap()
        })
        .clone();
    let naive_alloc = s.mean.as_secs_f64();
    println!(
        "  -> alloc/vgg16 speedup vs naive: {:.1}x",
        naive_alloc / fast_alloc
    );
    out.push(("alloc_vgg16_ms", Value::Num(fast_alloc * 1e3)));
    out.push(("alloc_vgg16_naive_ms", Value::Num(naive_alloc * 1e3)));
    out.push(("alloc_vgg16_speedup", Value::Num(naive_alloc / fast_alloc)));

    // Simulator event throughput: event-wheel vs naive rescan scheduler.
    let alloc = FlexAllocator::default()
        .allocate(&vgg, &board, QuantMode::W16A16)
        .unwrap();
    let s = b.bench("sim/vgg16/3frames", || sim::simulate(&alloc, 3)).clone();
    let sim_fast = s.mean.as_secs_f64();
    let sim_result = sim::simulate(&alloc, 3);
    let mcps = sim_result.makespan as f64 / sim_fast / 1e6;
    println!("  -> simulator speed: {mcps:.1} M simulated cycles / wall-second");
    let s = b
        .bench("sim/vgg16/3frames/naive", || {
            sim::engines::simulate_pipeline_naive(&alloc, 3)
        })
        .clone();
    let sim_naive = s.mean.as_secs_f64();
    println!("  -> sim speedup vs naive scheduler: {:.1}x", sim_naive / sim_fast);
    out.push(("sim_vgg16_3f_ms", Value::Num(sim_fast * 1e3)));
    out.push(("sim_vgg16_3f_naive_ms", Value::Num(sim_naive * 1e3)));
    out.push(("sim_mcycles_per_sec", Value::Num(mcps)));
    out.push(("sim_speedup", Value::Num(sim_naive / sim_fast)));

    // Design-space sweep: parallel + shared tables vs serial naive loop.
    let space = || DesignSpace {
        boards: vec![zedboard(), zc706(), zcu102(), vc707()],
        models: zoo::paper_nets(),
        modes: vec![QuantMode::W16A16, QuantMode::W8A8],
        ..Default::default()
    };
    let t0 = std::time::Instant::now();
    let points = space().sweep().expect("sweep");
    let sweep_fast = t0.elapsed().as_secs_f64();
    println!(
        "search/design-space: {} points in {:.1} ms (parallel, shared tables)",
        points.len(),
        sweep_fast * 1e3
    );
    let t0 = std::time::Instant::now();
    let mut n_serial = 0usize;
    for brd in [zedboard(), zc706(), zcu102(), vc707()] {
        for net in zoo::paper_nets() {
            for mode in [QuantMode::W16A16, QuantMode::W8A8] {
                let a = naive::allocate(&FlexAllocator::default(), &net, &brd, mode).unwrap();
                std::hint::black_box(a.evaluate());
                n_serial += 1;
            }
        }
    }
    let sweep_naive = t0.elapsed().as_secs_f64();
    assert_eq!(n_serial, points.len());
    println!(
        "search/design-space/serial-naive: {} points in {:.1} ms ({:.1}x speedup)",
        n_serial,
        sweep_naive * 1e3,
        sweep_naive / sweep_fast
    );
    out.push(("search_sweep_points", Value::Num(points.len() as f64)));
    out.push(("search_sweep_ms", Value::Num(sweep_fast * 1e3)));
    out.push(("search_sweep_naive_ms", Value::Num(sweep_naive * 1e3)));
    out.push(("search_sweep_speedup", Value::Num(sweep_naive / sweep_fast)));

    // JSON parse.
    let manifest_path = default_artifact_dir().join("manifest.json");
    if let Ok(text) = std::fs::read_to_string(&manifest_path) {
        b.bench("json/parse-manifest", || json::parse(&text).unwrap());
    }

    // PJRT execute.
    match Runtime::load(default_artifact_dir()) {
        Ok(rt) => {
            for name in ["tinycnn_b1_8b", "tinycnn_b8_8b", "vgg_micro_b4_8b"] {
                if let Ok(a) = rt.manifest().get(name) {
                    let input = vec![1i8; a.input_elems()];
                    let batch = a.batch;
                    let _ = rt.execute_i8(name, &input).unwrap(); // warm
                    let s = b
                        .bench(&format!("pjrt/{name}"), || {
                            rt.execute_i8(name, &input).unwrap()
                        })
                        .clone();
                    println!(
                        "  -> {:.1} frames/s through PJRT",
                        batch as f64 / s.mean.as_secs_f64()
                    );
                }
            }
        }
        Err(e) => println!("(skipping PJRT benches: {e})"),
    }
    b.finish();

    // Perf trajectory: machine-readable dump (repository root by default,
    // `--json PATH` to redirect).
    opts.write(&obj(out).to_pretty());
}
