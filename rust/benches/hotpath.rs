//! Bench: hot paths of the L3 coordinator stack, for the §Perf pass.
//!
//! - allocator end-to-end,
//! - the DES simulator's event throughput (simulated cycles per wall-second),
//! - JSON manifest parse,
//! - PJRT execute latency per artifact batch (needs `make artifacts`;
//!   skipped gracefully when absent).

use flexipipe::alloc::flex::FlexAllocator;
use flexipipe::alloc::Allocator;
use flexipipe::board::zc706;
use flexipipe::model::zoo;
use flexipipe::quant::QuantMode;
use flexipipe::runtime::{default_artifact_dir, Runtime};
use flexipipe::sim;
use flexipipe::util::bench::Bench;
use flexipipe::util::json;

fn main() {
    let mut b = Bench::with_budget_secs(1.5);
    let board = zc706();

    // Allocator.
    for net in [zoo::vgg16(), zoo::yolo()] {
        b.bench(&format!("alloc/{}", net.name), || {
            FlexAllocator::default()
                .allocate(&net, &board, QuantMode::W16A16)
                .unwrap()
        });
    }

    // Simulator event throughput.
    let alloc = FlexAllocator::default()
        .allocate(&zoo::vgg16(), &board, QuantMode::W16A16)
        .unwrap();
    let s = b.bench("sim/vgg16/3frames", || sim::simulate(&alloc, 3)).clone();
    let sim_result = sim::simulate(&alloc, 3);
    println!(
        "  -> simulator speed: {:.1} M simulated cycles / wall-second",
        sim_result.makespan as f64 / s.mean.as_secs_f64() / 1e6
    );

    // JSON parse.
    let manifest_path = default_artifact_dir().join("manifest.json");
    if let Ok(text) = std::fs::read_to_string(&manifest_path) {
        b.bench("json/parse-manifest", || json::parse(&text).unwrap());
    }

    // PJRT execute.
    match Runtime::load(default_artifact_dir()) {
        Ok(rt) => {
            for name in ["tinycnn_b1_8b", "tinycnn_b8_8b", "vgg_micro_b4_8b"] {
                if let Ok(a) = rt.manifest().get(name) {
                    let input = vec![1i8; a.input_elems()];
                    let batch = a.batch;
                    let _ = rt.execute_i8(name, &input).unwrap(); // warm
                    let s = b
                        .bench(&format!("pjrt/{name}"), || {
                            rt.execute_i8(name, &input).unwrap()
                        })
                        .clone();
                    println!(
                        "  -> {:.1} frames/s through PJRT",
                        batch as f64 / s.mean.as_secs_f64()
                    );
                }
            }
        }
        Err(e) => println!("(skipping PJRT benches: {e})"),
    }
    b.finish();
}
