//! Bench: fleet-scale placement, for the §Perf trajectory.
//!
//! - exhaustive tenant→board-subset enumeration on a twin-zedboard
//!   fleet (the exactness baseline),
//! - the same placement with branch-and-bound assignment pruning
//!   (`--prune`), byte-equal result asserted in-process,
//! - fleet failover (`FleetPlanner::replan`) migrating a displaced
//!   tenant onto the surviving twin.
//!
//! Emits machine-readable `BENCH_fleet.json` at the repository root,
//! recording pruned-vs-exhaustive node counts (assignments, bound
//! skips, board solves, cache hits) alongside the timings.

use flexipipe::board::zedboard;
use flexipipe::fault::{BoardLoss, FaultPlan};
use flexipipe::fleet::{FleetPlanner, FleetSpec};
use flexipipe::model::zoo;
use flexipipe::plan::Workload;
use flexipipe::quant::QuantMode;
use flexipipe::util::bench::BenchOpts;
use flexipipe::util::json::{num, obj, Value};
use std::path::Path;

fn fleet() -> FleetSpec {
    FleetSpec::new()
        .board("twin-a", zedboard(), 1.0)
        .board("twin-b", zedboard(), 1.0)
}

fn main() {
    let opts = BenchOpts::parse(
        2.0,
        Path::new(env!("CARGO_MANIFEST_DIR")).join("../BENCH_fleet.json"),
    );
    let mut b = opts.bench();
    let mut out: Vec<(&str, Value)> = Vec::new();

    let workload = Workload::new(QuantMode::W8A8)
        .tenant(zoo::tinycnn())
        .tenant(zoo::lenet());
    let exhaustive_planner = FleetPlanner::over(fleet()).steps(6);
    let pruned_planner = FleetPlanner::over(fleet()).steps(6).prune(true);

    let s = b
        .bench("fleet/place 2x2 exhaustive", || {
            exhaustive_planner.plan(&workload).unwrap()
        })
        .clone();
    out.push(("place_exhaustive_ms", Value::Num(s.mean.as_secs_f64() * 1e3)));

    let s = b.bench("fleet/place 2x2 pruned", || pruned_planner.plan(&workload).unwrap()).clone();
    out.push(("place_pruned_ms", Value::Num(s.mean.as_secs_f64() * 1e3)));

    // Pruning is an optimization, never an approximation: byte-equal.
    let exhaustive = exhaustive_planner.plan(&workload).unwrap();
    let pruned = pruned_planner.plan(&workload).unwrap();
    let dump = |s: &flexipipe::fleet::FleetPlanSet| -> Vec<String> {
        s.plans.iter().map(|p| p.to_json().to_pretty()).collect()
    };
    assert_eq!(dump(&exhaustive), dump(&pruned), "pruned != exhaustive");
    println!(
        "  -> {} assignments: {} solved / {} infeasible / {} bound-skipped (pruned)",
        pruned.stats.assignments,
        pruned.stats.solved,
        pruned.stats.infeasible,
        pruned.stats.bound_skipped
    );
    out.push(("frontier", num(exhaustive.plans.len())));
    out.push(("assignments", num(exhaustive.stats.assignments)));
    out.push(("exhaustive_board_solves", num(exhaustive.stats.board_solves)));
    out.push(("exhaustive_cache_hits", num(exhaustive.stats.cache_hits)));
    out.push(("pruned_bound_skipped", num(pruned.stats.bound_skipped)));
    out.push(("pruned_board_solves", num(pruned.stats.board_solves)));
    out.push(("pruned_cache_hits", num(pruned.stats.cache_hits)));

    // Failover: annihilate one twin, migrate its tenant onto the other.
    let incumbent = exhaustive
        .plans
        .iter()
        .find(|p| p.boards.len() == 2 && p.boards.iter().all(|pl| pl.plan.tenants.len() == 1))
        .expect("one-tenant-per-board split on the frontier")
        .clone();
    let faults = FaultPlan {
        board_loss: Some(BoardLoss {
            at_s: 0.25,
            survive_frac: 0.01,
        }),
        ..FaultPlan::none()
    };
    let lost = incumbent.boards[0].id.clone();
    let s = b
        .bench("fleet/replan board loss", || {
            exhaustive_planner.replan(&incumbent, &faults, &lost).unwrap()
        })
        .clone();
    out.push(("replan_ms", Value::Num(s.mean.as_secs_f64() * 1e3)));
    let outcome = exhaustive_planner.replan(&incumbent, &faults, &lost).unwrap();
    println!(
        "  -> lost {lost}: {} migrated, {} shed",
        outcome.migrated.len(),
        outcome.shed.len()
    );

    b.finish();

    opts.write(&obj(out).to_pretty());
}
