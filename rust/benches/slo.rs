//! Bench: latency-aware temporal scheduling — SLO-constrained interleaved
//! planning, the static-region overlay regime, and the drain-overlapped
//! schedule DES, for the §Perf trajectory.
//!
//! - SLO-interleaved search (two lenet tenants, tenant 0 under an 80 ms
//!   sojourn SLO, `max_interleave 2`): quanta × compositions × interleave
//!   factors scored analytically and SLO-filtered,
//! - overlay search (vgg16 + alexnet on a ZC706 at 8-bit): zero-reconfig
//!   superset-datapath schedules,
//! - `sim::simulate_schedule` of the best overlay plan — one period
//!   executed with drain-overlapped reconfiguration.
//!
//! Emits machine-readable `BENCH_slo.json` at the repository root,
//! alongside `BENCH_hotpath.json` / `BENCH_shard.json` /
//! `BENCH_timeshare.json`.

use flexipipe::alloc::Allocation;
use flexipipe::board::zc706;
use flexipipe::model::zoo;
use flexipipe::quant::QuantMode;
use flexipipe::shard::{Regime, ScheduleMode, Sharder, Tenant};
use flexipipe::sim;
use flexipipe::util::bench::BenchOpts;
use flexipipe::util::json::{obj, Value};
use std::path::Path;

fn slo_sharder() -> Sharder {
    Sharder {
        steps: 4,
        schedule: ScheduleMode::Temporal,
        max_interleave: 2,
        max_period_s: 0.1,
        calib_frames: 8,
        ..Sharder::new(
            zc706(),
            vec![
                Tenant::new(zoo::lenet(), QuantMode::W8A8).with_slo(0.080),
                Tenant::new(zoo::lenet(), QuantMode::W8A8),
            ],
        )
    }
}

fn overlay_sharder() -> Sharder {
    Sharder {
        steps: 8,
        schedule: ScheduleMode::Overlay,
        ..Sharder::new(
            zc706(),
            vec![
                Tenant::new(zoo::vgg16(), QuantMode::W8A8),
                Tenant::new(zoo::alexnet(), QuantMode::W8A8),
            ],
        )
    }
}

fn main() {
    let opts = BenchOpts::parse(
        2.0,
        Path::new(env!("CARGO_MANIFEST_DIR")).join("../BENCH_slo.json"),
    );
    let mut b = opts.bench();
    let mut out: Vec<(&str, Value)> = Vec::new();

    // SLO-constrained interleaved plan search.
    let s = b
        .bench("slo/lenet×2 interleaved plan", || slo_sharder().search().unwrap())
        .clone();
    out.push(("slo_search_ms", Value::Num(s.mean.as_secs_f64() * 1e3)));
    let slo = slo_sharder().search().unwrap();
    let interleaved = slo
        .plans
        .iter()
        .filter(|p| match &p.regime {
            Regime::Temporal(info) => info.interleave.iter().any(|&k| k > 1),
            Regime::Spatial => false,
        })
        .count();
    let best_lat = slo
        .plans
        .iter()
        .map(|p| p.latency_s[0])
        .fold(f64::INFINITY, f64::min);
    println!(
        "  -> {} SLO-satisfying plans ({} interleaved), best tenant-0 sojourn {:.1} ms",
        slo.plans.len(),
        interleaved,
        best_lat * 1e3
    );
    out.push(("slo_plans", Value::Num(slo.plans.len() as f64)));
    out.push(("slo_interleaved_plans", Value::Num(interleaved as f64)));
    out.push(("slo_best_sojourn_ms", Value::Num(best_lat * 1e3)));

    // Overlay (zero-reconfiguration superset datapath) search.
    let s = b
        .bench("slo/vgg16+alexnet overlay", || overlay_sharder().search().unwrap())
        .clone();
    out.push(("overlay_search_ms", Value::Num(s.mean.as_secs_f64() * 1e3)));
    let overlay = overlay_sharder().search().unwrap();
    println!(
        "  -> overlay: {} plans, {} on the frontier",
        overlay.plans.len(),
        overlay.frontier.len()
    );
    out.push(("overlay_plans", Value::Num(overlay.plans.len() as f64)));
    out.push((
        "overlay_min_fps",
        Value::Num(overlay.plans[overlay.best_min].min_fps),
    ));

    // Execute one drain-overlapped period of the best overlay plan.
    let best = &overlay.plans[overlay.best_min];
    let Regime::Temporal(info) = &best.regime else {
        unreachable!("overlay search returns temporal-regime plans")
    };
    let refs: Vec<&Allocation> = best.tenants.iter().map(|t| t.alloc.as_ref()).collect();
    let seq = info.schedule_slices();
    let s = b
        .bench("slo/sim one overlay period", || {
            sim::engines::simulate_schedule(&refs, &seq, true)
        })
        .clone();
    out.push(("overlay_sim_ms", Value::Num(s.mean.as_secs_f64() * 1e3)));
    let ts = sim::engines::simulate_schedule(&refs, &seq, true);
    println!(
        "  -> period {:.1} ms, dead {:.1}%, worst sojourn {:?} ms",
        ts.period_cycles as f64 / zc706().freq_hz * 1e3,
        ts.dead_frac * 100.0,
        ts.worst_sojourn
            .iter()
            .map(|&c| (c as f64 / zc706().freq_hz * 1e4).round() / 10.0)
            .collect::<Vec<_>>()
    );
    out.push(("overlay_sim_dead_frac", Value::Num(ts.dead_frac)));
    out.push((
        "overlay_sim_min_fps",
        Value::Num(ts.tenant_fps.iter().copied().fold(f64::INFINITY, f64::min)),
    ));

    b.finish();

    opts.write(&obj(out).to_pretty());
}
