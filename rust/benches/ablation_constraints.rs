//! Ablation bench (DESIGN.md §6): which of DNNBuilder's two constraints
//! costs how much? The paper argues its flexible activation buffer removes
//! (a) the power-of-2 restriction and (b) the matched-interface restriction
//! `C'_i = M'_{i−1}`. This bench isolates them:
//!
//! - `flex` — neither constraint (this work)
//! - `pow2` — the flex allocation with parallelisms rounded down to powers
//!   of 2 (what coarse BRAM banking would force)
//! - `dnnb` — both constraints ([3])

use flexipipe::alloc::baselines::DnnBuilderAllocator;
use flexipipe::alloc::flex::{refresh_figures, FlexAllocator};
use flexipipe::alloc::{Allocation, Allocator};
use flexipipe::board::zc706;
use flexipipe::model::zoo;
use flexipipe::quant::QuantMode;
use flexipipe::util::bench::Bench;

fn pow2_floor(n: usize) -> usize {
    if n == 0 {
        1
    } else {
        1 << (usize::BITS - 1 - n.leading_zeros())
    }
}

/// Constrain an existing flexible allocation to power-of-2 parallelisms.
fn pow2_constrain(mut a: Allocation) -> Allocation {
    let net = a.net.clone();
    for s in a.stages.iter_mut() {
        s.cfg.cp = pow2_floor(s.cfg.cp);
        s.cfg.mp = pow2_floor(s.cfg.mp);
    }
    refresh_figures(&net, a.mode, &mut a);
    a
}

fn main() {
    let mut b = Bench::with_budget_secs(0.5);
    let board = zc706();
    let mode = QuantMode::W16A16;

    println!(
        "{:<9} {:>8} {:>8} {:>8} {:>12} {:>12}",
        "model", "flex", "pow2", "dnnb", "pow2 cost", "dnnb cost"
    );
    for net in zoo::paper_nets() {
        let flex = FlexAllocator::default().allocate(&net, &board, mode).unwrap();
        let f = flex.evaluate();
        let p = pow2_constrain(flex.clone()).evaluate();
        let d = DnnBuilderAllocator
            .allocate(&net, &board, mode)
            .unwrap()
            .evaluate();
        println!(
            "{:<9} {:>8.0} {:>8.0} {:>8.0} {:>11.1}% {:>11.1}%",
            net.name,
            f.gops,
            p.gops,
            d.gops,
            100.0 * (1.0 - p.gops / f.gops),
            100.0 * (1.0 - d.gops / f.gops),
        );
        b.bench(&format!("ablate/{}/flex", net.name), || {
            FlexAllocator::default().allocate(&net, &board, mode).unwrap()
        });
        b.bench(&format!("ablate/{}/dnnb", net.name), || {
            DnnBuilderAllocator.allocate(&net, &board, mode).unwrap()
        });
    }
    b.finish();
}
