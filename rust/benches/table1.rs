//! Bench: regenerate Table I end-to-end (per-design-point allocation +
//! closed form + 3-frame simulation + power), timing each design point and
//! printing the regenerated rows — the paper's whole evaluation in one
//! `cargo bench` target.

use flexipipe::alloc::ArchKind;
use flexipipe::board::zc706;
use flexipipe::model::zoo;
use flexipipe::report;
use flexipipe::util::bench::Bench;

fn main() {
    let mut b = Bench::with_budget_secs(1.0);
    let board = zc706();
    for net in zoo::paper_nets() {
        for arch in [
            ArchKind::Recurrent,
            ArchKind::Fusion,
            ArchKind::DnnBuilder,
            ArchKind::FlexPipeline,
        ] {
            b.bench(&format!("table1/{}/{}", net.name, arch.label()), || {
                report::design_point(&net, &board, arch).unwrap()
            });
        }
    }
    b.finish();

    println!("\n== regenerated Table I ==");
    let rows = report::table1().unwrap();
    println!("{}", report::render(&rows, true));
    if let Some((r1, r2, r3)) = report::vgg16_speedups(&rows) {
        println!(
            "VGG16 speedups: {r1:.2}x vs [1] (paper 2.58x), {r2:.2}x vs [2] (paper 1.53x), \
             {r3:.2}x vs [3] (paper 1.35x)"
        );
    }
}
