//! Bench: Algorithm 2 behavior under a DDR bandwidth sweep (the paper's
//! Sec. 4.2 trade: raise row parallelism K → fewer weight reloads → less
//! bandwidth, more BRAM). Runs on the [`flexipipe::search`] engine — one
//! parallel sweep over bandwidth-mutated boards, each point confirmed by
//! the cycle simulator — then times the allocator and simulator hot paths.

use flexipipe::alloc::flex::FlexAllocator;
use flexipipe::alloc::Allocator;
use flexipipe::board::zc706;
use flexipipe::model::zoo;
use flexipipe::quant::QuantMode;
use flexipipe::search::DesignSpace;
use flexipipe::sim;
use flexipipe::util::bench::Bench;

fn main() {
    let mut b = Bench::with_budget_secs(0.5);
    let net = zoo::vgg16();

    let gbps = [2.0, 3.0, 4.0, 5.0, 6.4, 8.0, 10.0, 12.8];
    let ds = DesignSpace {
        boards: gbps
            .iter()
            .map(|&g| {
                let mut board = zc706();
                board.ddr_bytes_per_sec = g * 1e9;
                board.name = format!("zc706@{g}GBps");
                board
            })
            .collect(),
        models: vec![net.clone()],
        sim_frames: 2,
        ..Default::default()
    };
    let t0 = std::time::Instant::now();
    let points = ds.sweep().expect("sweep");
    let sweep_dt = t0.elapsed();

    println!(
        "{:>7} {:>9} {:>9} {:>8} {:>7} {:>10} {:>10}",
        "GB/s", "cf fps", "sim fps", "BRAM18", "max K", "B (GB/s)", "wstalls"
    );
    for (p, g) in points.iter().zip(&gbps) {
        let s = p.sim.as_ref().expect("sim_frames > 0");
        let wstalls: u64 = s.stages.iter().map(|st| st.stall_weights).sum();
        println!(
            "{:>7.1} {:>9.2} {:>9.2} {:>8} {:>7} {:>10.2} {:>10}",
            g,
            p.report.fps,
            s.fps,
            p.report.bram18,
            p.max_k,
            p.report.ddr_bytes_per_sec / 1e9,
            wstalls
        );
    }
    println!(
        "sweep: {} points (alloc + 2-frame sim each) in {sweep_dt:.2?}",
        points.len()
    );

    b.bench("alg2/vgg16/starved-4GBps", || {
        let mut board = zc706();
        board.ddr_bytes_per_sec = 4.0e9;
        FlexAllocator::default()
            .allocate(&net, &board, QuantMode::W16A16)
            .unwrap()
    });
    b.bench("sim/vgg16/2frames", || {
        let board = zc706();
        let alloc = FlexAllocator::default()
            .allocate(&net, &board, QuantMode::W16A16)
            .unwrap();
        sim::simulate(&alloc, 2)
    });
    b.finish();
}
