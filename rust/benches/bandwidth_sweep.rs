//! Bench: Algorithm 2 behavior under a DDR bandwidth sweep (the paper's
//! Sec. 4.2 trade: raise row parallelism K → fewer weight reloads → less
//! bandwidth, more BRAM). Prints the K/BRAM/fps trajectory and verifies
//! each point with the cycle simulator.

use flexipipe::alloc::flex::FlexAllocator;
use flexipipe::alloc::Allocator;
use flexipipe::board::zc706;
use flexipipe::model::zoo;
use flexipipe::quant::QuantMode;
use flexipipe::sim;
use flexipipe::util::bench::Bench;

fn main() {
    let mut b = Bench::with_budget_secs(0.5);
    let net = zoo::vgg16();

    println!(
        "{:>7} {:>9} {:>9} {:>8} {:>7} {:>10} {:>10}",
        "GB/s", "cf fps", "sim fps", "BRAM18", "max K", "B (GB/s)", "wstalls"
    );
    for gbps in [2.0, 3.0, 4.0, 5.0, 6.4, 8.0, 10.0, 12.8] {
        let mut board = zc706();
        board.ddr_bytes_per_sec = gbps * 1e9;
        let alloc = FlexAllocator::default()
            .allocate(&net, &board, QuantMode::W16A16)
            .unwrap();
        let r = alloc.evaluate();
        let s = sim::simulate(&alloc, 2);
        let max_k = alloc.stages.iter().map(|st| st.cfg.k).max().unwrap_or(1);
        let wstalls: u64 = s.stages.iter().map(|st| st.stall_weights).sum();
        println!(
            "{:>7.1} {:>9.2} {:>9.2} {:>8} {:>7} {:>10.2} {:>10}",
            gbps,
            r.fps,
            s.fps,
            r.bram18,
            max_k,
            r.ddr_bytes_per_sec / 1e9,
            wstalls
        );
    }

    b.bench("alg2/vgg16/starved-4GBps", || {
        let mut board = zc706();
        board.ddr_bytes_per_sec = 4.0e9;
        FlexAllocator::default()
            .allocate(&net, &board, QuantMode::W16A16)
            .unwrap()
    });
    b.bench("sim/vgg16/2frames", || {
        let board = zc706();
        let alloc = FlexAllocator::default()
            .allocate(&net, &board, QuantMode::W16A16)
            .unwrap();
        sim::simulate(&alloc, 2)
    });
    b.finish();
}
