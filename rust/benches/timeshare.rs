//! Bench: the time-multiplexed sharding planner and the reconfiguration-
//! aware DES, for the §Perf trajectory.
//!
//! - temporal plan search (vgg16 + alexnet on a ZC706 at 8-bit): per-tenant
//!   full-board allocation + DES calibration once, then quanta ×
//!   compositions scored analytically,
//! - merged (auto) search: spatial split space + temporal schedules into
//!   one frontier,
//! - `sim::simulate_schedule` of the best min-fps temporal plan — one
//!   schedule period executed drain → (drain-overlapped) reconfigure →
//!   refill.
//!
//! Emits machine-readable `BENCH_timeshare.json` at the repository root,
//! alongside `BENCH_hotpath.json` / `BENCH_shard.json`.

use flexipipe::alloc::Allocation;
use flexipipe::board::zc706;
use flexipipe::model::zoo;
use flexipipe::quant::QuantMode;
use flexipipe::shard::{Regime, ScheduleMode, Sharder, Tenant};
use flexipipe::sim;
use flexipipe::util::bench::BenchOpts;
use flexipipe::util::json::{obj, Value};
use std::path::Path;

fn sharder(schedule: ScheduleMode) -> Sharder {
    Sharder {
        steps: 8,
        schedule,
        ..Sharder::new(
            zc706(),
            vec![
                Tenant::new(zoo::vgg16(), QuantMode::W8A8),
                Tenant::new(zoo::alexnet(), QuantMode::W8A8),
            ],
        )
    }
}

fn main() {
    let opts = BenchOpts::parse(
        2.0,
        Path::new(env!("CARGO_MANIFEST_DIR")).join("../BENCH_timeshare.json"),
    );
    let mut b = opts.bench();
    let mut out: Vec<(&str, Value)> = Vec::new();

    // Temporal-only plan search.
    let s = b
        .bench("timeshare/vgg16+alexnet/plan", || {
            sharder(ScheduleMode::Temporal).search().unwrap()
        })
        .clone();
    out.push(("timeshare_search_ms", Value::Num(s.mean.as_secs_f64() * 1e3)));
    let temporal = sharder(ScheduleMode::Temporal).search().unwrap();
    println!(
        "  -> {} temporal plans, {} on the frontier",
        temporal.plans.len(),
        temporal.frontier.len()
    );
    out.push(("timeshare_plans", Value::Num(temporal.plans.len() as f64)));

    // Merged (auto) search: both regimes into one frontier.
    let s = b
        .bench("timeshare/vgg16+alexnet/auto", || {
            sharder(ScheduleMode::Auto).search().unwrap()
        })
        .clone();
    out.push(("auto_search_ms", Value::Num(s.mean.as_secs_f64() * 1e3)));
    let auto = sharder(ScheduleMode::Auto).search().unwrap();
    let n_temporal = auto.plans.iter().filter(|p| p.regime.is_temporal()).count();
    println!(
        "  -> auto: {} plans ({} temporal), merged frontier {}",
        auto.plans.len(),
        n_temporal,
        auto.frontier.len()
    );
    out.push(("auto_plans", Value::Num(auto.plans.len() as f64)));
    out.push(("auto_frontier", Value::Num(auto.frontier.len() as f64)));
    out.push(("auto_temporal_plans", Value::Num(n_temporal as f64)));

    // Execute one period of the best min-fps temporal plan — through the
    // same drain-overlapped schedule DES the planner's admission assumed
    // (a serial re-charge of the full reconfiguration would overrun
    // slices the planner sized against the overlap credit).
    let best = &temporal.plans[temporal.best_min];
    let Regime::Temporal(info) = &best.regime else {
        unreachable!("temporal search returns temporal plans")
    };
    let refs: Vec<&Allocation> = best.tenants.iter().map(|t| t.alloc.as_ref()).collect();
    let seq = info.schedule_slices();
    let s = b
        .bench("timeshare/sim one period", || {
            sim::engines::simulate_schedule(&refs, &seq, true)
        })
        .clone();
    out.push(("timeshare_sim_ms", Value::Num(s.mean.as_secs_f64() * 1e3)));
    let ts = sim::engines::simulate_schedule(&refs, &seq, true);
    println!(
        "  -> period {:.1} ms, dead {:.1}%, per-tenant fps {:?}",
        ts.period_cycles as f64 / zc706().freq_hz * 1e3,
        ts.dead_frac * 100.0,
        ts.tenant_fps.iter().map(|f| (f * 10.0).round() / 10.0).collect::<Vec<_>>()
    );
    // Executed-schedule dead fraction (refill counts as busy) — the
    // analytic `TemporalInfo::dead_frac` is a stricter definition.
    out.push(("timeshare_sim_dead_frac", Value::Num(ts.dead_frac)));
    out.push((
        "timeshare_min_fps_analytic",
        Value::Num(best.min_fps),
    ));
    out.push((
        "timeshare_min_fps_sim",
        Value::Num(ts.tenant_fps.iter().copied().fold(f64::INFINITY, f64::min)),
    ));

    b.finish();

    opts.write(&obj(out).to_pretty());
}
