//! Bench: pipeline balance (paper Fig. 1(b)'s argument — idle cycles come
//! from unbalanced `T_row`). Reports the per-stage cycles/frame spread of
//! the full allocator per net, and times the allocator itself.

use flexipipe::alloc::flex::FlexAllocator;
use flexipipe::alloc::Allocator;
use flexipipe::board::zc706;
use flexipipe::model::zoo;
use flexipipe::quant::QuantMode;
use flexipipe::util::bench::Bench;

fn spread(cycles: &[u64]) -> f64 {
    let max = *cycles.iter().max().unwrap() as f64;
    let busy: f64 = cycles.iter().map(|&c| c as f64).sum();
    busy / (cycles.len() as f64 * max)
}

fn main() {
    let mut b = Bench::with_budget_secs(1.0);
    let board = zc706();
    for net in zoo::paper_nets() {
        b.bench(&format!("allocate/{}", net.name), || {
            FlexAllocator::default()
                .allocate(&net, &board, QuantMode::W16A16)
                .unwrap()
        });
    }
    b.finish();

    println!("\n== per-stage balance (compute stages, 16b) ==");
    println!(
        "{:<9} {:>14} {:>14} {:>10}",
        "model", "max cycles", "min cycles", "balance"
    );
    for net in zoo::paper_nets() {
        let alloc = FlexAllocator::default()
            .allocate(&net, &board, QuantMode::W16A16)
            .unwrap();
        let cycles: Vec<u64> = alloc
            .stages
            .iter()
            .zip(alloc.stage_cycles())
            .filter(|(s, _)| alloc.net.layers[s.layer_idx].uses_dsps())
            .map(|(_, c)| c)
            .collect();
        println!(
            "{:<9} {:>14} {:>14} {:>9.1}%",
            net.name,
            cycles.iter().max().unwrap(),
            cycles.iter().min().unwrap(),
            spread(&cycles) * 100.0
        );
    }
    println!("(balance = mean busy fraction at the pipeline beat; 100% = perfectly balanced)");
}
