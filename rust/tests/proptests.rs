//! Property tests over the allocator/engine/simulator invariants
//! (DESIGN.md §7), using the in-tree deterministic harness
//! (`flexipipe::util::prop` — the offline vendor set has no proptest).

use flexipipe::alloc::flex::{decompose, naive, FlexAllocator, PhaseStair};
use flexipipe::alloc::Allocator;
use flexipipe::board::{zc706, Board};
use flexipipe::engine::div_ceil;
use flexipipe::engine::linebuf::{frame_fits, LineBuffer};
use flexipipe::model::{conv, fc, pool, Layer, Network};
use flexipipe::quant::{self, QuantMode};
use flexipipe::sim;
use flexipipe::util::json;
use flexipipe::util::prop::{check, Rng};

/// Random small-but-valid network: alternating conv/pool with occasional
/// trailing FC layers — the space Algorithm 1 must handle.
fn random_net(rng: &mut Rng) -> Network {
    let mut layers = Vec::new();
    let mut c = *rng.pick(&[1usize, 3, 4]);
    let mut h = *rng.pick(&[16usize, 28, 32, 56]);
    let mut w = h;
    let n_conv = rng.urange(1, 5);
    for _ in 0..n_conv {
        let m = *rng.pick(&[4usize, 8, 16, 24, 32, 64]);
        let r = *rng.pick(&[1usize, 3, 5]);
        let stride = if h > 8 && rng.flip() { 2 } else { 1 };
        let pad = r / 2;
        let oh = (h + 2 * pad - r) / stride + 1;
        let ow = (w + 2 * pad - r) / stride + 1;
        layers.push(conv(c, m, oh, ow, r, stride, pad));
        c = m;
        h = oh;
        w = ow;
        if h >= 4 && rng.flip() {
            let ph = h / 2;
            let pw = w / 2;
            layers.push(pool(c, ph, pw, 2, 2));
            h = ph;
            w = pw;
        }
    }
    if rng.flip() {
        layers.push(fc(c * h * w, rng.urange(4, 64)));
    }
    Network {
        name: "prop".into(),
        input: (
            match &layers[0] {
                Layer::Conv(cv) => cv.c,
                _ => c,
            },
            match &layers[0] {
                Layer::Conv(cv) => (cv.h - 1) * cv.stride + cv.r - 2 * cv.pad,
                _ => h,
            },
            match &layers[0] {
                Layer::Conv(cv) => (cv.w - 1) * cv.stride + cv.s - 2 * cv.pad,
                _ => w,
            },
        ),
        layers,
    }
}

fn random_board(rng: &mut Rng) -> Board {
    let mut b = zc706();
    b.dsps = rng.urange(64, 2048);
    b.bram36 = rng.urange(200, 1200);
    b.ddr_bytes_per_sec = rng.urange(2, 16) as f64 * 1e9;
    b
}

#[test]
fn prop_allocation_respects_board_budgets() {
    check("dsp-budget", 60, |rng| {
        let net = random_net(rng);
        if net.validate().is_err() {
            return; // generator produced degenerate geometry; skip
        }
        let board = random_board(rng);
        let mode = *rng.pick(&[QuantMode::W8A8, QuantMode::W16A16]);
        let alloc = FlexAllocator::default().allocate(&net, &board, mode).unwrap();
        let r = alloc.evaluate();
        assert!(
            r.dsps <= board.dsps,
            "net={net:?} used {} of {} DSPs",
            r.dsps,
            board.dsps
        );
        assert!(r.fps > 0.0 && r.gops.is_finite());
    });
}

#[test]
fn prop_decompose_within_dims_and_budget() {
    check("decompose", 300, |rng| {
        let c = rng.urange(1, 512);
        let m = rng.urange(1, 512);
        let rs = *rng.pick(&[1usize, 9, 25, 49, 121]);
        let budget = rng.urange(rs, 4000);
        let (cp, mp) = decompose(c, m, rs, budget);
        assert!(cp >= 1 && cp <= c, "cp={cp} c={c}");
        assert!(mp >= 1 && mp <= m, "mp={mp} m={m}");
        assert!(
            cp * mp * rs <= budget.max(rs),
            "{cp}x{mp}x{rs} > budget {budget}"
        );
    });
}

#[test]
fn prop_more_dsps_never_slower() {
    check("monotone-dsps", 25, |rng| {
        let net = random_net(rng);
        if net.validate().is_err() {
            return;
        }
        let mut small = zc706();
        small.dsps = rng.urange(64, 512);
        let mut big = small.clone();
        big.dsps = small.dsps * 2;
        let fs = FlexAllocator::default()
            .allocate(&net, &small, QuantMode::W16A16)
            .unwrap()
            .evaluate();
        let fb = FlexAllocator::default()
            .allocate(&net, &big, QuantMode::W16A16)
            .unwrap()
            .evaluate();
        assert!(
            fb.fps >= fs.fps * 0.999,
            "doubling DSPs slowed {}: {} -> {}",
            net.name,
            fs.fps,
            fb.fps
        );
    });
}

#[test]
fn prop_phase_stair_matches_decompose() {
    // The staircase lookup must reproduce the reference decomposition's
    // phase count for every (dims, granule, budget) — this is the
    // invariant that lets Algorithm 1 replace the O(C·M) search with a
    // binary search.
    check("phase-stair", 300, |rng| {
        let c = rng.urange(1, 600);
        let m = rng.urange(1, 600);
        let rs = *rng.pick(&[1usize, 9, 25, 49]);
        let budget = rng.urange(rs, 6000);
        let (cp, mp) = decompose(c, m, rs, budget);
        let want = div_ceil(c, cp) as u64 * div_ceil(m, mp) as u64;
        let stair = PhaseStair::build(c, m);
        let got = stair.phases_at(((budget / rs).max(1)) as u64);
        assert_eq!(got, want, "c={c} m={m} rs={rs} budget={budget}");
    });
}

#[test]
fn prop_optimized_allocator_matches_naive_exactly() {
    // The heap/staircase Algorithm 1 and the clone-free Algorithm 2 must
    // produce bit-identical allocations to the seed's naive reference.
    check("alloc-equivalence", 40, |rng| {
        let net = random_net(rng);
        if net.validate().is_err() {
            return;
        }
        let board = random_board(rng);
        let mode = *rng.pick(&[QuantMode::W8A8, QuantMode::W16A16]);
        let a = FlexAllocator::default();
        let fast = a.allocate(&net, &board, mode).unwrap();
        let slow = naive::allocate(&a, &net, &board, mode).unwrap();
        for (i, (f, s)) in fast.stages.iter().zip(&slow.stages).enumerate() {
            assert_eq!(f.cfg, s.cfg, "stage {i} diverged for {net:?} on {board:?}");
            assert_eq!(f.figures, s.figures, "stage {i} figures diverged");
        }
        let (rf, rs) = (fast.evaluate(), slow.evaluate());
        assert_eq!(rf.t_frame_cycles, rs.t_frame_cycles);
        assert_eq!(rf.bottleneck, rs.bottleneck);
        assert_eq!(rf.fps.to_bits(), rs.fps.to_bits());
        assert_eq!(rf.dsps, rs.dsps);
        assert_eq!(rf.bram18, rs.bram18);
        assert_eq!(
            rf.ddr_demand_bytes_per_sec.to_bits(),
            rs.ddr_demand_bytes_per_sec.to_bits()
        );
    });
}

#[test]
fn prop_evaluate_perf_matches_full_evaluate() {
    // The geometry-free perf report must agree bit-for-bit with the full
    // evaluation on every shared field (the delta-evaluation invariant
    // raise_k depends on).
    check("perf-vs-full", 40, |rng| {
        let net = random_net(rng);
        if net.validate().is_err() {
            return;
        }
        let board = random_board(rng);
        let mode = *rng.pick(&[QuantMode::W8A8, QuantMode::W16A16]);
        let alloc = FlexAllocator::default().allocate(&net, &board, mode).unwrap();
        let (p, r) = (alloc.evaluate_perf(), alloc.evaluate());
        assert_eq!(p.t_frame_cycles, r.t_frame_cycles);
        assert_eq!(p.bottleneck, r.bottleneck);
        assert_eq!(p.fps.to_bits(), r.fps.to_bits());
        assert_eq!(p.gops.to_bits(), r.gops.to_bits());
        assert_eq!(p.mults, r.mults);
        assert_eq!(p.dsps, r.dsps);
        assert_eq!(p.dsp_efficiency.to_bits(), r.dsp_efficiency.to_bits());
        assert_eq!(p.ddr_bytes_per_sec.to_bits(), r.ddr_bytes_per_sec.to_bits());
        assert_eq!(
            p.ddr_demand_bytes_per_sec.to_bits(),
            r.ddr_demand_bytes_per_sec.to_bits()
        );
        assert_eq!(p.stage_cycles, r.stage_cycles);
    });
}

#[test]
fn prop_event_wheel_sim_matches_naive_scheduler() {
    // The ready-queue DES must replay the naive full-rescan scheduler's
    // event sequence exactly.
    check("sim-equivalence", 20, |rng| {
        let net = random_net(rng);
        if net.validate().is_err() {
            return;
        }
        let board = random_board(rng);
        let alloc = FlexAllocator::default()
            .allocate(&net, &board, QuantMode::W16A16)
            .unwrap();
        let frames = rng.urange(1, 5);
        let fast = sim::engines::simulate_pipeline(&alloc, frames);
        let slow = sim::engines::simulate_pipeline_naive(&alloc, frames);
        assert_eq!(fast.makespan, slow.makespan, "{net:?}");
        assert_eq!(
            fast.cycles_per_frame.to_bits(),
            slow.cycles_per_frame.to_bits()
        );
        assert_eq!(fast.ddr_bytes, slow.ddr_bytes);
        assert_eq!(fast.stages, slow.stages);
    });
}

#[test]
fn prop_sim_matches_closed_form_when_unconstrained() {
    // On a bandwidth-rich board the simulated steady-state beat must agree
    // with Eq. 2–4 closely (the DES validates the closed form).
    check("sim-vs-closed-form", 15, |rng| {
        let net = random_net(rng);
        if net.validate().is_err() {
            return;
        }
        let mut board = zc706();
        board.dsps = rng.urange(128, 1024);
        board.ddr_bytes_per_sec = 64e9; // effectively unconstrained
        let alloc = FlexAllocator::default()
            .allocate(&net, &board, QuantMode::W16A16)
            .unwrap();
        let cf = alloc.evaluate();
        let s = sim::simulate(&alloc, 4);
        let ratio = s.cycles_per_frame / cf.t_frame_cycles as f64;
        assert!(
            (0.95..1.6).contains(&ratio),
            "sim/cf ratio {ratio:.3} (cf={} sim={:.0}) for {:?}",
            cf.t_frame_cycles,
            s.cycles_per_frame,
            net
        );
    });
}

#[test]
fn prop_line_buffer_sizing_always_suffices() {
    // The paper's R + G(K−1) + K_prev rowBuffers must survive a whole frame
    // of concurrent reads/writes for any geometry.
    check("linebuf", 300, |rng| {
        let r = rng.urange(1, 7);
        let g = rng.urange(1, 3);
        let k = rng.urange(1, 6);
        let kp = rng.urange(1, 6);
        let h = rng.urange(r.max(g * k), 64);
        let slots = LineBuffer::required_slots(r, g, k, kp);
        frame_fits(slots, h, r, g, k, kp)
            .unwrap_or_else(|e| panic!("r={r} g={g} k={k} kp={kp} h={h}: {e}"));
    });
}

#[test]
fn prop_shift_sat_matches_i128_reference() {
    check("shift-sat", 500, |rng| {
        let v = rng.range(i64::MIN / 4, i64::MAX / 4);
        let shift = rng.urange(0, 31) as u32;
        let bits = *rng.pick(&[8usize, 16]);
        let got = quant::shift_sat(v, shift, bits);
        // reference in i128
        let shifted = (v as i128) >> shift;
        let hi = (1i128 << (bits - 1)) - 1;
        let lo = -(1i128 << (bits - 1));
        let want = shifted.clamp(lo, hi) as i64;
        assert_eq!(got, want, "v={v} shift={shift} bits={bits}");
    });
}

#[test]
fn prop_json_round_trip() {
    fn random_value(rng: &mut Rng, depth: usize) -> json::Value {
        match rng.urange(0, if depth > 2 { 3 } else { 5 }) {
            0 => json::Value::Null,
            1 => json::Value::Bool(rng.flip()),
            2 => json::Value::Num(rng.range(-1_000_000, 1_000_000) as f64),
            3 => json::Value::Str(
                (0..rng.urange(0, 12))
                    .map(|_| *rng.pick(&['a', 'Ω', '"', '\\', '\n', '7', '😀', ' ']))
                    .collect(),
            ),
            4 => json::Value::Arr(
                (0..rng.urange(0, 4))
                    .map(|_| random_value(rng, depth + 1))
                    .collect(),
            ),
            _ => {
                let mut m = std::collections::BTreeMap::new();
                for i in 0..rng.urange(0, 4) {
                    m.insert(format!("k{i}"), random_value(rng, depth + 1));
                }
                json::Value::Obj(m)
            }
        }
    }
    check("json-round-trip", 200, |rng| {
        let v = random_value(rng, 0);
        let text = v.to_string();
        let back = json::parse(&text).unwrap_or_else(|e| panic!("{text}: {e}"));
        assert_eq!(v, back, "round trip failed for {text}");
        // pretty printing must parse to the same value too
        assert_eq!(json::parse(&v.to_pretty()).unwrap(), v);
    });
}

#[test]
fn prop_quant_conv_identity_composition() {
    // conv(identity kernel) ∘ conv(identity kernel) == identity (checks the
    // golden Rust datapath composes without drift).
    use flexipipe::quant::ops::{conv_fixed, Chw, ConvParams};
    check("conv-identity", 50, |rng| {
        let c = rng.urange(1, 4);
        let h = rng.urange(2, 10);
        let w = rng.urange(2, 10);
        let mut x = Chw::zeros(c, h, w);
        for ci in 0..c {
            for y in 0..h {
                for xi in 0..w {
                    x.set(ci, y, xi, rng.range(-128, 127));
                }
            }
        }
        // identity: M=C, 1x1 kernel, w[m][c] = 1 iff m==c
        let mut wv = vec![0i64; c * c];
        for i in 0..c {
            wv[i * c + i] = 1;
        }
        let p = ConvParams {
            w: wv,
            m: c,
            c,
            r: 1,
            s: 1,
            bias: vec![0; c],
            lshift: vec![0; c],
            rshift: vec![0; c],
        };
        let y = conv_fixed(&x, &p, 1, 0, QuantMode::W8A8, false);
        let z = conv_fixed(&y, &p, 1, 0, QuantMode::W8A8, false);
        assert_eq!(x.data, z.data);
    });
}
