//! Plan-diff algebra: `diff(a, a)` is empty; `apply(a, diff(a, b))`
//! reconstructs `b` byte-identically; a diff's drain-overlapped
//! reconfiguration cost is bounded by the target's full-swap cost in both
//! directions; removals are free and explicit; and corrupt diffs are
//! rejected without touching the source plan.

use flexipipe::board::zedboard;
use flexipipe::fault::{PlanDiff, TenantOp};
use flexipipe::model::zoo;
use flexipipe::plan::{DeploymentPlan, Planner, Workload, PLAN_VERSION};
use flexipipe::quant::QuantMode;

fn fixture_path() -> &'static str {
    concat!(
        env!("CARGO_MANIFEST_DIR"),
        "/../examples/plans/vgg16_alexnet_zc706.json"
    )
}

/// Two feasible plans for the *same* workload with different θ splits —
/// every tenant pairs up but both payloads differ, so the diff must
/// price two drain-overlapped swaps.
fn plan_pair() -> (DeploymentPlan, DeploymentPlan) {
    let set = Planner::on(zedboard())
        .steps(8)
        .plan(
            &Workload::new(QuantMode::W8A8)
                .tenant(zoo::tinycnn())
                .tenant(zoo::lenet()),
        )
        .unwrap();
    let a = set.plans[set.best].clone();
    let b = set
        .plans
        .iter()
        .find(|p| p.tenants[0].dsp_parts != a.tenants[0].dsp_parts)
        .expect("an 8-step spatial search holds more than one split")
        .clone();
    (a, b)
}

#[test]
fn identical_plans_diff_empty_with_zero_cost() {
    let (a, _) = plan_pair();
    let fixture = DeploymentPlan::load(fixture_path()).unwrap();
    for plan in [&a, &fixture] {
        let d = plan.diff(plan).unwrap();
        assert!(d.is_empty(), "self-diff must be empty");
        assert_eq!(d.cost_cycles(), 0);
        assert!(d.removed.is_empty());
        for (j, op) in d.ops.iter().enumerate() {
            assert!(
                matches!(op, TenantOp::Keep { from } if *from == j),
                "self-diff op {j} is not an in-place keep"
            );
        }
        // Applying the empty diff is the identity, byte for byte.
        let same = plan.apply(&d).unwrap();
        assert_eq!(plan.to_json().to_pretty(), same.to_json().to_pretty());
    }
}

#[test]
fn apply_round_trips_byte_identically_both_directions() {
    // The algebra the failover path stands on: a.apply(diff(a → b))
    // serializes exactly as b, whichever direction the transition runs.
    let (a, b) = plan_pair();
    let ab = a.diff(&b).unwrap();
    assert!(!ab.is_empty(), "distinct splits must produce a real diff");
    assert_eq!(
        a.apply(&ab).unwrap().to_json().to_pretty(),
        b.to_json().to_pretty(),
        "apply(a, diff(a, b)) diverged from b"
    );
    let ba = b.diff(&a).unwrap();
    assert_eq!(
        b.apply(&ba).unwrap().to_json().to_pretty(),
        a.to_json().to_pretty(),
        "apply(b, diff(b, a)) diverged from a"
    );
}

#[test]
fn diff_cost_bounded_by_full_swap_both_directions() {
    // Drain overlap can only hide cycles: each swap charges at most its
    // full partial-bitstream cost, so the whole transition is bounded by
    // streaming the target plan from scratch — in both directions.
    let (a, b) = plan_pair();
    for (from, to) in [(&a, &b), (&b, &a)] {
        let d = from.diff(to).unwrap();
        for op in &d.ops {
            if let TenantOp::Change { reconfig, .. } | TenantOp::Add { reconfig, .. } = op {
                assert!(
                    reconfig.overlap_cycles <= reconfig.full_cycles,
                    "overlap credit exceeds the swap it hides under"
                );
                assert_eq!(
                    reconfig.charged_cycles(),
                    reconfig.full_cycles - reconfig.overlap_cycles
                );
            }
        }
        let full = to.full_swap_cycles().unwrap();
        assert!(
            d.cost_cycles() <= full,
            "diff cost {} exceeds the full-swap bound {full}",
            d.cost_cycles()
        );
    }
}

#[test]
fn removed_tenants_are_explicit_and_cost_nothing() {
    // Dropping a region streams nothing in: a target that keeps tenant 0
    // byte-identical and drops tenant 1 diffs to one in-place keep plus
    // one explicit removal, at zero reconfiguration cost — and the diff
    // still apply-round-trips.
    let (a, _) = plan_pair();
    let mut b = a.clone();
    b.tenants.truncate(1);
    let d = a.diff(&b).unwrap();
    assert!(!d.is_empty(), "a removal is a real transition");
    assert_eq!(d.cost_cycles(), 0);
    assert_eq!(d.ops.len(), 1);
    assert!(matches!(&d.ops[0], TenantOp::Keep { from: 0 }));
    assert_eq!(d.removed.len(), 1);
    assert_eq!(d.removed[0].from, 1);
    assert_eq!(d.removed[0].net, a.tenants[1].net.name);
    assert_eq!(
        a.apply(&d).unwrap().to_json().to_pretty(),
        b.to_json().to_pretty()
    );
}

#[test]
fn added_tenants_pay_the_full_uncredited_swap() {
    // The reverse transition: bringing a tenant in has no outgoing
    // pipeline to drain under, so its swap is charged in full.
    let (a, _) = plan_pair();
    let mut solo = a.clone();
    solo.tenants.truncate(1);
    let d = solo.diff(&a).unwrap();
    assert_eq!(d.ops.len(), 2);
    assert!(matches!(&d.ops[0], TenantOp::Keep { from: 0 }));
    let TenantOp::Add { tenant, reconfig } = &d.ops[1] else {
        panic!("re-admitting a tenant must be an add, got {:?}", d.ops[1]);
    };
    assert_eq!(tenant.net.name, a.tenants[1].net.name);
    assert_eq!(reconfig.overlap_cycles, 0, "an add has no drain to hide under");
    assert!(reconfig.full_cycles > 0);
    assert_eq!(d.cost_cycles(), reconfig.full_cycles);
    assert!(d.cost_cycles() <= a.full_swap_cycles().unwrap());
    assert_eq!(
        solo.apply(&d).unwrap().to_json().to_pretty(),
        a.to_json().to_pretty()
    );
}

#[test]
fn plan_level_changes_are_detected_and_applied() {
    // A transition that only retunes a plan-level knob (here the split
    // granularity) is not empty, costs no swap, and apply reproduces it.
    let (a, _) = plan_pair();
    let mut b = a.clone();
    b.steps *= 2;
    let d = a.diff(&b).unwrap();
    assert!(!d.is_empty());
    assert_eq!(d.cost_cycles(), 0, "a knob change streams no bitstream");
    assert_eq!(d.steps, Some(b.steps));
    assert!(d.board.is_none() && d.mode.is_none());
    assert_eq!(
        a.apply(&d).unwrap().to_json().to_pretty(),
        b.to_json().to_pretty()
    );
}

#[test]
fn wire_codec_round_trips_and_applies_identically() {
    // The control plane ships diffs as JSON (`POST /plan/apply`); a
    // decoded diff must be indistinguishable from the locally-computed
    // one — same wire bytes, and apply() reconstructs the same target
    // plan byte for byte.
    let (a, b) = plan_pair();
    let d = a.diff(&b).unwrap();
    assert!(!d.is_empty());
    let text = d.to_wire_json().to_pretty();
    let decoded = PlanDiff::from_wire_json(&flexipipe::util::json::parse(&text).unwrap()).unwrap();
    assert_eq!(
        text,
        decoded.to_wire_json().to_pretty(),
        "wire encoding must be stable through a decode/encode cycle"
    );
    assert_eq!(
        a.apply(&d).unwrap().to_json().to_pretty(),
        a.apply(&decoded).unwrap().to_json().to_pretty(),
        "a decoded diff must apply exactly like the original"
    );
}

#[test]
fn wire_codec_carries_full_16_bit_tenant_payloads() {
    // The checked-in 16-bit plan exercises the codec's data path: an Add
    // op ships the complete W16A16 tenant payload over the wire, and the
    // receiving side reconstructs the two-tenant plan byte-identically
    // without ever seeing the target plan file.
    let fixture = DeploymentPlan::load(fixture_path()).unwrap();
    let mut solo = fixture.clone();
    solo.tenants.truncate(1);
    let d = solo.diff(&fixture).unwrap();
    assert!(
        d.ops.iter().any(|op| matches!(op, TenantOp::Add { .. })),
        "re-admitting the second tenant must be an add"
    );
    let text = d.to_wire_json().to_pretty();
    let decoded = PlanDiff::from_wire_json(&flexipipe::util::json::parse(&text).unwrap()).unwrap();
    assert_eq!(
        solo.apply(&decoded).unwrap().to_json().to_pretty(),
        fixture.to_json().to_pretty(),
        "wire-shipped 16-bit payloads must reconstruct the fixture exactly"
    );
}

#[test]
fn wire_codec_rejects_bad_versions_ops_and_shapes() {
    use flexipipe::util::json::{parse, Value};
    let (a, b) = plan_pair();
    let text = a.diff(&b).unwrap().to_wire_json().to_pretty();

    let bumped = text.replacen("\"version\": 1", "\"version\": 9", 1);
    assert_ne!(text, bumped);
    let err = PlanDiff::from_wire_json(&parse(&bumped).unwrap()).unwrap_err();
    assert!(err.to_string().contains("wire version 9"), "{err}");

    let noop = a.diff(&a).unwrap().to_wire_json().to_pretty();
    let mangled = noop.replacen("\"keep\"", "\"merge\"", 1);
    assert_ne!(noop, mangled);
    let err = PlanDiff::from_wire_json(&parse(&mangled).unwrap()).unwrap_err();
    assert!(err.to_string().contains("unknown diff op 'merge'"), "{err}");

    // A temporal section without a regime label is structurally invalid.
    let mut v = parse(&noop).unwrap();
    if let Value::Obj(m) = &mut v {
        m.insert("temporal".into(), Value::Num(1.0));
    }
    let err = PlanDiff::from_wire_json(&v).unwrap_err();
    assert!(err.to_string().contains("without a 'regime'"), "{err}");

    // Overlap credit larger than the swap it hides under is rejected at
    // decode time — before apply() could mis-price the transition.
    let bad = PlanDiff {
        ops: vec![TenantOp::Add {
            tenant: a.tenants[0].clone(),
            reconfig: flexipipe::fault::ReconfigStep {
                net: a.tenants[0].net.name.clone(),
                full_cycles: 5,
                overlap_cycles: 9,
            },
        }],
        removed: Vec::new(),
        board: None,
        mode: None,
        steps: None,
        regime: None,
        reconfig_model: None,
    };
    let err = PlanDiff::from_wire_json(&bad.to_wire_json()).unwrap_err();
    assert!(err.to_string().contains("exceeds full_cycles"), "{err}");
}

#[test]
fn apply_rejects_corrupt_diffs() {
    let (a, _) = plan_pair();
    let empty_diff = |ops: Vec<TenantOp>| PlanDiff {
        ops,
        removed: Vec::new(),
        board: None,
        mode: None,
        steps: None,
        regime: None,
        reconfig_model: None,
    };
    // Out-of-range source index.
    let err = a.apply(&empty_diff(vec![TenantOp::Keep { from: 7 }])).unwrap_err();
    assert!(err.to_string().contains("source tenant 7"), "{err}");
    // The same source claimed twice.
    let err = a
        .apply(&empty_diff(vec![
            TenantOp::Keep { from: 0 },
            TenantOp::Keep { from: 0 },
        ]))
        .unwrap_err();
    assert!(err.to_string().contains("more than once"), "{err}");
    // A diff that leaves no tenants at all.
    let err = a.apply(&empty_diff(Vec::new())).unwrap_err();
    assert!(err.to_string().contains("no tenants"), "{err}");
    // Version mismatches refuse to diff rather than mis-pair tenants.
    let mut other = a.clone();
    other.version = PLAN_VERSION + 1;
    let err = a.diff(&other).unwrap_err();
    assert!(err.to_string().contains("version"), "{err}");
}
