//! CLI-level acceptance for the plan-centric flow: the deprecated `shard`
//! spelling is a byte-identical alias of `plan`, and a plan file emitted
//! by `flexipipe plan --json` is accepted by `simulate --plan` and
//! `serve --plan` — with the re-simulation matching the planning
//! process's DES validation bit-for-bit across the process boundary.

use flexipipe::plan::DeploymentPlan;
use flexipipe::sim::{Simulate, Simulator};
use std::path::{Path, PathBuf};
use std::process::Command;

fn bin() -> &'static str {
    env!("CARGO_BIN_EXE_flexipipe")
}

fn tmp_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("flexipipe_cli_plan").join(name);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn run_ok(args: &[&str]) -> String {
    let out = Command::new(bin()).args(args).output().unwrap();
    assert!(
        out.status.success(),
        "flexipipe {args:?} failed:\nstdout: {}\nstderr: {}",
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr)
    );
    String::from_utf8_lossy(&out.stdout).into_owned()
}

#[test]
fn shard_spelling_is_a_byte_identical_alias_of_plan() {
    // The satellite-pinned back-compat case: the old `shard` spelling and
    // the new `plan` spelling produce identical frontier JSON.
    let dir = tmp_dir("alias");
    let old = dir.join("old.json");
    let new = dir.join("new.json");
    let flags = |out: &Path| {
        vec![
            "--models".to_string(),
            "vgg16,alexnet".to_string(),
            "--board".to_string(),
            "zc706".to_string(),
            "--schedule".to_string(),
            "auto".to_string(),
            "--shard-steps".to_string(),
            "4".to_string(),
            "--max-period".to_string(),
            "0.2".to_string(),
            "--json".to_string(),
            out.to_str().unwrap().to_string(),
        ]
    };
    let mut shard_args = vec!["shard".to_string()];
    shard_args.extend(flags(&old));
    let mut plan_args = vec!["plan".to_string()];
    plan_args.extend(flags(&new));
    run_ok(&shard_args.iter().map(String::as_str).collect::<Vec<_>>());
    run_ok(&plan_args.iter().map(String::as_str).collect::<Vec<_>>());
    let old_text = std::fs::read_to_string(&old).unwrap();
    let new_text = std::fs::read_to_string(&new).unwrap();
    assert!(!old_text.is_empty());
    assert_eq!(old_text, new_text, "shard and plan spellings diverged");
    // The emitted document is a loadable deployment plan.
    let plan = DeploymentPlan::load(&new).unwrap();
    assert_eq!(plan.tenants.len(), 2);
}

#[test]
fn planned_file_feeds_simulate_and_serve() {
    // plan → simulate --plan → serve --plan, all through the binary, on
    // an 8-bit workload the SimBackend can serve.
    let dir = tmp_dir("flow");
    let plan_path = dir.join("plan8.json");
    run_ok(&[
        "plan",
        "--models",
        "tinycnn,lenet",
        "--board",
        "zedboard",
        "--bits",
        "8",
        "--shard-steps",
        "8",
        "--sim-frames",
        "2",
        "--json",
        plan_path.to_str().unwrap(),
    ]);

    let sim_out = run_ok(&[
        "simulate",
        "--plan",
        plan_path.to_str().unwrap(),
        "--frames",
        "2",
    ]);
    assert!(sim_out.contains("tinycnn"), "{sim_out}");
    assert!(sim_out.contains("lenet"), "{sim_out}");

    let serve_out = run_ok(&[
        "serve",
        "--plan",
        plan_path.to_str().unwrap(),
        "--frames",
        "6",
    ]);
    assert!(serve_out.contains("served"), "{serve_out}");
    assert!(serve_out.contains("tinycnn"), "{serve_out}");

    // Cross-process bit-identity: re-simulating the file in this process
    // reproduces the planning process's recorded DES validation exactly.
    let plan = DeploymentPlan::load(&plan_path).unwrap();
    let report = Simulator { frames: 2 }.simulate(&plan).unwrap();
    for (t, r) in report.tenants.iter().enumerate() {
        if let Some(recorded) = plan.tenants[t].record.as_ref().and_then(|rec| rec.sim_fps) {
            assert_eq!(
                r.fps.to_bits(),
                recorded.to_bits(),
                "tenant {t}: cross-process re-simulation diverged"
            );
        }
    }
}
