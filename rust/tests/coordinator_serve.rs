//! Integration: the serving coordinator under concurrent load.

use flexipipe::coordinator::{BatchPolicy, Coordinator};
use flexipipe::runtime::{default_artifact_dir, read_i8, Manifest};
use std::sync::Arc;
use std::time::Duration;

fn setup() -> Option<(Manifest, Vec<i8>, Vec<i8>, usize, usize, usize)> {
    let dir = default_artifact_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIPPED: run `make artifacts` first");
        return None;
    }
    let manifest = Manifest::load(dir.join("manifest.json")).unwrap();
    let v = manifest.variants("tinycnn", 8);
    let a = v[0];
    let golden_in = read_i8(dir.join(&a.golden.input)).unwrap();
    let golden_out = read_i8(dir.join(&a.golden.output)).unwrap();
    let (e, o, n) = (a.golden.frame_elems, a.golden.out_elems, a.golden.frames);
    Some((manifest, golden_in, golden_out, e, o, n))
}

#[test]
fn concurrent_clients_all_get_correct_answers() {
    let Some((_, golden_in, golden_out, elems, oe, n)) = setup() else {
        return;
    };
    let coord = Arc::new(
        Coordinator::start(
            default_artifact_dir(),
            "tinycnn",
            8,
            BatchPolicy {
                max_wait: Duration::from_millis(2),
                link_latency: Duration::ZERO,
            },
        )
        .unwrap(),
    );
    let golden_in = Arc::new(golden_in);
    let golden_out = Arc::new(golden_out);

    let mut clients = Vec::new();
    for t in 0..4 {
        let coord = coord.clone();
        let gin = golden_in.clone();
        let gout = golden_out.clone();
        clients.push(std::thread::spawn(move || {
            for i in 0..24 {
                let g = (t * 7 + i) % n;
                let out = coord.infer(gin[g * elems..(g + 1) * elems].to_vec()).unwrap();
                assert_eq!(
                    out,
                    &gout[g * oe..(g + 1) * oe],
                    "client {t}, request {i} (golden frame {g})"
                );
            }
        }));
    }
    for c in clients {
        c.join().unwrap();
    }
    let stats = coord.stats();
    assert_eq!(stats.requests, 96);
    // With 4 concurrent clients and a 2 ms window, at least some requests
    // should have been coalesced into batches > 1.
    assert!(
        stats.batches <= stats.requests,
        "batches {} > requests {}",
        stats.batches,
        stats.requests
    );
}

#[test]
fn submit_rejects_malformed_frames() {
    let Some(_) = setup() else { return };
    let coord = Coordinator::start(
        default_artifact_dir(),
        "tinycnn",
        8,
        BatchPolicy::default(),
    )
    .unwrap();
    assert!(coord.submit(vec![0i8; 5]).is_err());
}

#[test]
fn start_rejects_unknown_net() {
    let Some(_) = setup() else { return };
    let err = match Coordinator::start(
        default_artifact_dir(),
        "resnet152",
        8,
        BatchPolicy::default(),
    ) {
        Ok(_) => panic!("unknown net must not start"),
        Err(e) => e,
    };
    assert!(err.to_string().contains("no artifacts"));
}

#[test]
fn shutdown_drains_inflight_requests() {
    let Some((_, golden_in, _, elems, _, _)) = setup() else {
        return;
    };
    let coord = Coordinator::start(
        default_artifact_dir(),
        "tinycnn",
        8,
        BatchPolicy::default(),
    )
    .unwrap();
    let mut rxs = Vec::new();
    for _ in 0..8 {
        rxs.push(coord.submit(golden_in[..elems].to_vec()).unwrap());
    }
    let stats = coord.shutdown();
    // every submitted request got an answer before shutdown completed
    for rx in rxs {
        assert!(rx.recv().unwrap().is_ok());
    }
    assert_eq!(stats.requests, 8);
}
