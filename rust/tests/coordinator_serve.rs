//! Integration: the serving coordinator under concurrent load.
//!
//! The default tests run on the deterministic in-process
//! [`flexipipe::runtime::SimBackend`] — no artifacts, no PJRT — so the
//! whole batching/queueing/shutdown surface is exercised in artifact-free
//! CI. The original PJRT variants are kept as `#[ignore]`d extras: run
//! `cargo test -- --ignored` after `make artifacts` with real xla bindings.

use flexipipe::coordinator::{BatchPolicy, Coordinator};
use flexipipe::model::zoo;
use flexipipe::runtime::{default_artifact_dir, read_i8, Backend, Manifest, SimBackend};
use flexipipe::util::prop::Rng;
use std::sync::Arc;
use std::time::Duration;

/// Deterministic input frames, same stream the oracle sees.
fn frames(elems: usize, n: usize) -> Vec<i8> {
    let mut rng = Rng::new(0xF00D);
    (0..elems * n).map(|_| rng.range(-128, 127) as i8).collect()
}

#[test]
fn concurrent_clients_all_get_correct_answers() {
    let net = zoo::tinycnn();
    let oracle = SimBackend::new(&net, &[1]).unwrap();
    let elems = oracle.frame_elems();
    let n = 8;
    let input = Arc::new(frames(elems, n));
    let golden: Arc<Vec<Vec<i8>>> = Arc::new(
        (0..n)
            .map(|g| oracle.forward_frame(&input[g * elems..(g + 1) * elems]).unwrap())
            .collect(),
    );

    let coord = Arc::new(
        Coordinator::start_sim(
            &net,
            &[1, 4, 8],
            BatchPolicy {
                max_wait: Duration::from_millis(2),
                link_latency: Duration::ZERO,
            },
        )
        .unwrap(),
    );

    let mut clients = Vec::new();
    for t in 0..4usize {
        let coord = coord.clone();
        let input = input.clone();
        let golden = golden.clone();
        clients.push(std::thread::spawn(move || {
            for i in 0..24 {
                let g = (t * 7 + i) % n;
                let out = coord
                    .infer(input[g * elems..(g + 1) * elems].to_vec())
                    .unwrap();
                assert_eq!(out, golden[g], "client {t}, request {i} (frame {g})");
            }
        }));
    }
    for c in clients {
        c.join().unwrap();
    }
    let stats = coord.stats();
    assert_eq!(stats.requests, 96);
    assert!(
        stats.batches <= stats.requests,
        "batches {} > requests {}",
        stats.batches,
        stats.requests
    );
}

#[test]
fn forced_timeout_produces_padded_partial_batch() {
    // Batching policy under starvation: only a batch-4 variant exists, two
    // frames arrive, and the max_wait timeout must force one padded batch
    // whose real slots still get correct answers.
    let net = zoo::tinycnn();
    let oracle = SimBackend::new(&net, &[1]).unwrap();
    let elems = oracle.frame_elems();
    let input = frames(elems, 2);

    let coord = Coordinator::start_sim(
        &net,
        &[4],
        BatchPolicy {
            max_wait: Duration::from_millis(200),
            link_latency: Duration::ZERO,
        },
    )
    .unwrap();
    let rx0 = coord.submit(input[..elems].to_vec()).unwrap();
    let rx1 = coord.submit(input[elems..].to_vec()).unwrap();
    let out0 = rx0.recv().unwrap().unwrap();
    let out1 = rx1.recv().unwrap().unwrap();
    assert_eq!(out0, oracle.forward_frame(&input[..elems]).unwrap());
    assert_eq!(out1, oracle.forward_frame(&input[elems..]).unwrap());

    let stats = coord.shutdown();
    assert_eq!(stats.requests, 2);
    assert_eq!(stats.batches, 1, "both frames must share one batch");
    assert_eq!(stats.padded_frames, 2, "a 4-slot batch with 2 frames pads 2");
    assert_eq!(stats.batch_sizes, vec![(4, 2)]);
}

#[test]
fn submit_rejects_malformed_frames() {
    let coord = Coordinator::start_sim(&zoo::tinycnn(), &[1], BatchPolicy::default()).unwrap();
    assert!(coord.submit(vec![0i8; 5]).is_err());
}

#[test]
fn start_sim_rejects_unsupported_nets() {
    // AlexNet's grouped convolutions are outside the sim datapath.
    let err = match Coordinator::start_sim(&zoo::alexnet(), &[1], BatchPolicy::default()) {
        Ok(_) => panic!("grouped-conv net must not start"),
        Err(e) => e,
    };
    assert!(err.to_string().contains("grouped"));
}

#[test]
fn shutdown_drains_inflight_requests() {
    let net = zoo::lenet();
    let oracle = SimBackend::new(&net, &[1]).unwrap();
    let elems = oracle.frame_elems();
    let input = frames(elems, 1);
    let coord = Coordinator::start_sim(&net, &[1, 4], BatchPolicy::default()).unwrap();
    let mut rxs = Vec::new();
    for _ in 0..8 {
        rxs.push(coord.submit(input.clone()).unwrap());
    }
    let stats = coord.shutdown();
    // every submitted request got an answer before shutdown completed
    for rx in rxs {
        assert!(rx.recv().unwrap().is_ok());
    }
    assert_eq!(stats.requests, 8);
}

// ---------------------------------------------------------------------------
// PJRT variants: artifact-gated extras (`make artifacts` + real bindings).
// ---------------------------------------------------------------------------

fn pjrt_setup() -> (Vec<i8>, Vec<i8>, usize, usize, usize) {
    let dir = default_artifact_dir();
    let manifest = Manifest::load(dir.join("manifest.json")).expect("run `make artifacts` first");
    let a = manifest.variants("tinycnn", 8)[0];
    let golden_in = read_i8(dir.join(&a.golden.input)).unwrap();
    let golden_out = read_i8(dir.join(&a.golden.output)).unwrap();
    (
        golden_in,
        golden_out,
        a.golden.frame_elems,
        a.golden.out_elems,
        a.golden.frames,
    )
}

#[test]
#[ignore = "needs `make artifacts` + real PJRT bindings"]
fn pjrt_concurrent_clients_all_get_correct_answers() {
    let (golden_in, golden_out, elems, oe, n) = pjrt_setup();
    let coord = Arc::new(
        Coordinator::start(
            default_artifact_dir(),
            "tinycnn",
            8,
            BatchPolicy {
                max_wait: Duration::from_millis(2),
                link_latency: Duration::ZERO,
            },
        )
        .unwrap(),
    );
    let golden_in = Arc::new(golden_in);
    let golden_out = Arc::new(golden_out);

    let mut clients = Vec::new();
    for t in 0..4 {
        let coord = coord.clone();
        let gin = golden_in.clone();
        let gout = golden_out.clone();
        clients.push(std::thread::spawn(move || {
            for i in 0..24 {
                let g = (t * 7 + i) % n;
                let out = coord.infer(gin[g * elems..(g + 1) * elems].to_vec()).unwrap();
                assert_eq!(
                    out,
                    &gout[g * oe..(g + 1) * oe],
                    "client {t}, request {i} (golden frame {g})"
                );
            }
        }));
    }
    for c in clients {
        c.join().unwrap();
    }
    assert_eq!(coord.stats().requests, 96);
}

#[test]
#[ignore = "needs `make artifacts` + real PJRT bindings"]
fn pjrt_start_rejects_unknown_net() {
    let err = match Coordinator::start(
        default_artifact_dir(),
        "resnet152",
        8,
        BatchPolicy::default(),
    ) {
        Ok(_) => panic!("unknown net must not start"),
        Err(e) => e,
    };
    assert!(err.to_string().contains("no artifacts"));
}

#[test]
#[ignore = "needs `make artifacts` + real PJRT bindings"]
fn pjrt_shutdown_drains_inflight_requests() {
    let (golden_in, _, elems, _, _) = pjrt_setup();
    let coord = Coordinator::start(
        default_artifact_dir(),
        "tinycnn",
        8,
        BatchPolicy::default(),
    )
    .unwrap();
    let mut rxs = Vec::new();
    for _ in 0..8 {
        rxs.push(coord.submit(golden_in[..elems].to_vec()).unwrap());
    }
    let stats = coord.shutdown();
    for rx in rxs {
        assert!(rx.recv().unwrap().is_ok());
    }
    assert_eq!(stats.requests, 8);
}
