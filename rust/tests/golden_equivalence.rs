//! Golden equivalence: the optimized hot paths must reproduce the seed's
//! naive implementation bit-for-bit on the paper's headline design point
//! (VGG16 on ZC706, 16-bit) — allocation, closed-form report, and the
//! 3-frame cycle simulation. This is the acceptance gate for every future
//! change to `alloc::flex`, `alloc::Allocation::evaluate*`, or `sim`:
//! optimizations may change *how* the numbers are computed, never *what*
//! they are.

use flexipipe::alloc::flex::{naive, FlexAllocator};
use flexipipe::alloc::Allocator;
use flexipipe::board::zc706;
use flexipipe::model::zoo;
use flexipipe::quant::QuantMode;
use flexipipe::sim;

#[test]
fn vgg16_zc706_allocation_is_bit_identical_to_naive() {
    let net = zoo::vgg16();
    let board = zc706();
    let a = FlexAllocator::default();
    let fast = a.allocate(&net, &board, QuantMode::W16A16).unwrap();
    let slow = naive::allocate(&a, &net, &board, QuantMode::W16A16).unwrap();

    assert_eq!(fast.stages.len(), slow.stages.len());
    for (i, (f, s)) in fast.stages.iter().zip(&slow.stages).enumerate() {
        assert_eq!(f.cfg, s.cfg, "stage {i} (C',M',K) diverged");
        assert_eq!(f.figures, s.figures, "stage {i} figures diverged");
    }

    let (rf, rs) = (fast.evaluate(), slow.evaluate());
    assert_eq!(rf.t_frame_cycles, rs.t_frame_cycles);
    assert_eq!(rf.bottleneck, rs.bottleneck);
    assert_eq!(rf.fps.to_bits(), rs.fps.to_bits());
    assert_eq!(rf.gops.to_bits(), rs.gops.to_bits());
    assert_eq!(rf.mults, rs.mults);
    assert_eq!(rf.dsps, rs.dsps);
    assert_eq!(rf.dsp_efficiency.to_bits(), rs.dsp_efficiency.to_bits());
    assert_eq!(rf.bram18, rs.bram18);
    assert_eq!(rf.luts, rs.luts);
    assert_eq!(rf.ffs, rs.ffs);
    assert_eq!(rf.ddr_bytes_per_sec.to_bits(), rs.ddr_bytes_per_sec.to_bits());
    assert_eq!(
        rf.ddr_demand_bytes_per_sec.to_bits(),
        rs.ddr_demand_bytes_per_sec.to_bits()
    );
    assert_eq!(rf.stage_cycles, rs.stage_cycles);
}

#[test]
fn vgg16_zc706_sim3_is_bit_identical_to_naive() {
    let alloc = FlexAllocator::default()
        .allocate(&zoo::vgg16(), &zc706(), QuantMode::W16A16)
        .unwrap();
    let fast = sim::engines::simulate_pipeline(&alloc, 3);
    let slow = sim::engines::simulate_pipeline_naive(&alloc, 3);
    assert_eq!(fast.frames, slow.frames);
    assert_eq!(fast.makespan, slow.makespan);
    assert_eq!(
        fast.cycles_per_frame.to_bits(),
        slow.cycles_per_frame.to_bits()
    );
    assert_eq!(fast.fps.to_bits(), slow.fps.to_bits());
    assert_eq!(fast.gops.to_bits(), slow.gops.to_bits());
    assert_eq!(fast.dsp_efficiency.to_bits(), slow.dsp_efficiency.to_bits());
    assert_eq!(fast.ddr_bytes, slow.ddr_bytes);
    assert_eq!(fast.ddr_utilization.to_bits(), slow.ddr_utilization.to_bits());
    assert_eq!(fast.stages, slow.stages);
}

#[test]
fn vgg16_zc706_evaluate_perf_is_bit_identical_to_evaluate() {
    let alloc = FlexAllocator::default()
        .allocate(&zoo::vgg16(), &zc706(), QuantMode::W16A16)
        .unwrap();
    let (p, r) = (alloc.evaluate_perf(), alloc.evaluate());
    assert_eq!(p.t_frame_cycles, r.t_frame_cycles);
    assert_eq!(p.fps.to_bits(), r.fps.to_bits());
    assert_eq!(p.gops.to_bits(), r.gops.to_bits());
    assert_eq!(p.dsp_efficiency.to_bits(), r.dsp_efficiency.to_bits());
    assert_eq!(
        p.ddr_demand_bytes_per_sec.to_bits(),
        r.ddr_demand_bytes_per_sec.to_bits()
    );
    assert_eq!(p.stage_cycles, r.stage_cycles);
}

#[test]
fn all_paper_nets_allocations_match_naive_at_both_precisions() {
    for net in zoo::paper_nets() {
        for mode in [QuantMode::W16A16, QuantMode::W8A8] {
            let a = FlexAllocator::default();
            let fast = a.allocate(&net, &zc706(), mode).unwrap();
            let slow = naive::allocate(&a, &net, &zc706(), mode).unwrap();
            for (f, s) in fast.stages.iter().zip(&slow.stages) {
                assert_eq!(f.cfg, s.cfg, "{} {mode}", net.name);
            }
            assert_eq!(
                fast.evaluate().fps.to_bits(),
                slow.evaluate().fps.to_bits(),
                "{} {mode}",
                net.name
            );
        }
    }
}
