//! Latency-aware temporal scheduling: SLO-driven slice interleaving,
//! drain-overlapped reconfiguration, the static-region overlay regime, and
//! the calibration conservativeness the analytic schedule stands on.

use flexipipe::alloc::flex::FlexAllocator;
use flexipipe::alloc::{Allocation, Allocator};
use flexipipe::board::zc706;
use flexipipe::model::{conv, zoo, Network};
use flexipipe::quant::QuantMode;
use flexipipe::shard::{Regime, ScheduleMode, ShardResult, Sharder, Tenant};
use flexipipe::sim::{self, ScheduleSlice};
use flexipipe::util::prop::check;

// ---------------------------------------------------------------------------
// Calibration conservativeness (the fix-satellite property)
// ---------------------------------------------------------------------------

#[test]
fn max_gap_extrapolation_never_undershoots_longer_runs() {
    // The analytic schedule admits batches by extrapolating past its
    // calibration window with the window's *largest* completion gap. That
    // is only conservative if no later gap exceeds the window's max — the
    // property the planner's debug assertion checks per search, asserted
    // here across workloads, precisions, and window sizes against one
    // long reference run.
    for (net, mode) in [
        (zoo::tinycnn(), QuantMode::W8A8),
        (zoo::lenet(), QuantMode::W16A16),
        (zoo::vgg_micro(), QuantMode::W8A8),
        (zoo::zf(), QuantMode::W8A8),
    ] {
        let alloc = FlexAllocator::default().allocate(&net, &zc706(), mode).unwrap();
        let long = sim::simulate(&alloc, 12);
        for w in 2..=6 {
            let beat = long.frame_done[..w]
                .windows(2)
                .map(|p| p[1] - p[0])
                .max()
                .unwrap()
                .max(1);
            for n in w + 1..=12 {
                let est = long.frame_done[w - 1] + (n - w) as u64 * beat;
                assert!(
                    est >= long.frame_done[n - 1],
                    "{} ({mode}) window {w}: extrapolated makespan {est} undershoots \
                     the true {n}-frame makespan {}",
                    net.name,
                    long.frame_done[n - 1]
                );
            }
        }
    }
}

#[test]
fn drain_credit_never_exceeds_longer_runs_drain_tails() {
    // The drain-overlap credit's symmetric assumption: the planner
    // credits the *smallest* drain tail observed in its calibration
    // window, and the DES charges the predecessor batch's *actual*
    // last-frame drain — so no later frame's drain may dip below the
    // window minimum, or the executed schedule would charge more swap
    // than the planner budgeted. Windows match the planner's defaults
    // (≥ 6 calibration frames; the drain transient settles within the
    // first few frames, so the window min is the converged tail).
    for (net, mode) in [
        (zoo::tinycnn(), QuantMode::W8A8),
        (zoo::lenet(), QuantMode::W16A16),
        (zoo::vgg_micro(), QuantMode::W8A8),
        (zoo::zf(), QuantMode::W8A8),
    ] {
        let alloc = FlexAllocator::default().allocate(&net, &zc706(), mode).unwrap();
        let long = sim::simulate(&alloc, 12);
        for w in 6..=8 {
            let dmin = long.frame_done[..w]
                .iter()
                .zip(&long.input_done[..w])
                .map(|(&f, &i)| f - i)
                .min()
                .unwrap();
            for n in w + 1..=12 {
                let drain = long.frame_done[n - 1] - long.input_done[n - 1];
                assert!(
                    drain >= dmin,
                    "{} ({mode}) window {w}: frame {n}'s drain {drain} dips below \
                     the calibrated credit {dmin}",
                    net.name
                );
            }
        }
    }
}

#[test]
fn prop_extrapolation_conservative_under_bandwidth_pressure() {
    // Same property with the DDR port randomly starved: congestion changes
    // the gap structure but must never grow gaps past the window max.
    check("extrapolation-conservative", 8, |rng| {
        let mut board = zc706();
        board.ddr_bytes_per_sec = rng.urange(2, 13) as f64 * 1e9;
        let net = match rng.urange(0, 2) {
            0 => zoo::tinycnn(),
            1 => zoo::lenet(),
            _ => zoo::vgg_micro(),
        };
        let mode = *rng.pick(&[QuantMode::W8A8, QuantMode::W16A16]);
        let alloc = FlexAllocator::default().allocate(&net, &board, mode).unwrap();
        let long = sim::simulate(&alloc, 10);
        let w = rng.urange(2, 5);
        let beat = long.frame_done[..w]
            .windows(2)
            .map(|p| p[1] - p[0])
            .max()
            .unwrap()
            .max(1);
        for n in w + 1..=10 {
            let est = long.frame_done[w - 1] + (n - w) as u64 * beat;
            assert!(est >= long.frame_done[n - 1], "{}: undershoot at n={n}", net.name);
        }
    });
}

// ---------------------------------------------------------------------------
// Drain-overlapped reconfiguration
// ---------------------------------------------------------------------------

fn alloc_of(net: &Network, mode: QuantMode) -> Allocation {
    FlexAllocator::default().allocate(net, &zc706(), mode).unwrap()
}

#[test]
fn prop_drain_overlap_never_exceeds_serial_period() {
    // Acceptance property: whatever the batches, slices, and swap costs,
    // overlapping reconfiguration with the outgoing tenant's drain can
    // only remove dead cycles — the executed period is never longer than
    // PR 3's serial drain → reconfigure → refill cost, and every tenant's
    // effective rate is at least the serial one.
    let pool = [
        alloc_of(&zoo::tinycnn(), QuantMode::W8A8),
        alloc_of(&zoo::lenet(), QuantMode::W8A8),
        alloc_of(&zoo::vgg_micro(), QuantMode::W8A8),
    ];
    check("drain-overlap-dominates", 10, |rng| {
        let n = rng.urange(2, 3);
        let allocs: Vec<&Allocation> = (0..n)
            .map(|_| *rng.pick(&[&pool[0], &pool[1], &pool[2]]))
            .collect();
        let frames: Vec<usize> = (0..n).map(|_| rng.urange(1, 4)).collect();
        let solos: Vec<u64> = allocs
            .iter()
            .zip(&frames)
            .map(|(a, &f)| sim::simulate(a, f).makespan)
            .collect();
        let slices: Vec<u64> = solos
            .iter()
            .map(|&m| m * rng.urange(1, 3) as u64 / 2 + rng.urange(0, 20_000) as u64)
            .collect();
        let reconfig: Vec<u64> = (0..n).map(|_| rng.urange(0, 200_000) as u64).collect();
        let serial = sim::engines::simulate_timeshared(&allocs, &frames, &slices, &reconfig);
        let seq: Vec<ScheduleSlice> = (0..n)
            .map(|i| ScheduleSlice {
                tenant: i,
                frames: frames[i],
                slice_cycles: slices[i],
                reconfig_cycles: reconfig[i],
            })
            .collect();
        let overlapped = sim::engines::simulate_schedule(&allocs, &seq, true);
        assert!(
            overlapped.period_cycles <= serial.period_cycles,
            "drain overlap stretched the period: {} > {}",
            overlapped.period_cycles,
            serial.period_cycles
        );
        for t in 0..n {
            assert!(overlapped.tenant_fps[t] >= serial.tenant_fps[t] - 1e-12);
        }
        for s in &overlapped.slices {
            assert!(s.overlap_cycles <= s.reconfig_cycles);
        }
    });
}

#[test]
fn zero_depth_pipelines_degenerate_to_serial_cost() {
    // Regression pin for the overlap model: a single-stage pipeline's
    // input side finishes with the frame itself (no drain window), so a
    // drain-overlapped schedule of zero-depth tenants charges exactly the
    // PR-3 serial reconfiguration cost.
    let net = Network {
        name: "conv1".into(),
        input: (16, 32, 32),
        layers: vec![conv(16, 16, 32, 32, 3, 1, 1)],
    };
    let alloc = alloc_of(&net, QuantMode::W8A8);
    assert_eq!(alloc.stages.len(), 1, "zero-depth fixture must be one stage");
    let solo = sim::simulate(&alloc, 2);
    let seq: Vec<ScheduleSlice> = (0..2)
        .map(|t| ScheduleSlice {
            tenant: t,
            frames: 2,
            slice_cycles: solo.makespan / 2, // tight: overlap would show
            reconfig_cycles: 40_000,
        })
        .collect();
    let overlapped = sim::engines::simulate_schedule(&[&alloc, &alloc], &seq, true);
    let serial = sim::engines::simulate_schedule(&[&alloc, &alloc], &seq, false);
    assert_eq!(overlapped.period_cycles, serial.period_cycles);
    assert_eq!(overlapped.dead_cycles, serial.dead_cycles);
    assert!(overlapped.slices.iter().all(|s| s.overlap_cycles == 0));
    assert_eq!(overlapped.worst_sojourn, serial.worst_sojourn);
}

// ---------------------------------------------------------------------------
// SLO-driven interleaving (the acceptance scenario)
// ---------------------------------------------------------------------------

fn slo_sharder(max_interleave: usize, slo_s: Option<f64>) -> Sharder {
    // Tenant 0 (lenet) is small and latency-constrained; tenants 1 and 2
    // are two *identical* big-fill pipelines (vgg16) whose slice needs pin
    // the quantum — the configuration where one-slice-per-period planning
    // provably cannot serve tenant 0 between the two blocks, but k = 2
    // interleaving can. Run in the free-reconfiguration (overlay) limit so
    // the two blocks stay exactly symmetric (identical nets → identical
    // calibrations → identical admission needs), which makes the k = 2
    // win structural rather than calibration-dependent. Batches are
    // capped *inside* the calibration window so the analytic makespans
    // are exact (the sojourn agreement below is then pure schedule
    // arithmetic).
    let t0 = match slo_s {
        Some(s) => Tenant::new(zoo::lenet(), QuantMode::W8A8).with_slo(s),
        None => Tenant::new(zoo::lenet(), QuantMode::W8A8),
    };
    Sharder {
        steps: 8,
        schedule: ScheduleMode::Temporal,
        reconfig: flexipipe::shard::ReconfigModel::zero(),
        max_interleave,
        max_period_s: 0.4,
        calib_frames: 8,
        max_slice_frames: 6,
        ..Sharder::new(
            zc706(),
            vec![
                t0,
                Tenant::new(zoo::vgg16(), QuantMode::W8A8),
                Tenant::new(zoo::vgg16(), QuantMode::W8A8),
            ],
        )
    }
}

fn min_latency(r: &ShardResult, tenant: usize) -> f64 {
    r.plans
        .iter()
        .map(|p| p.latency_s[tenant])
        .fold(f64::INFINITY, f64::min)
}

#[test]
fn interleaving_admits_slo_infeasible_tenant_and_des_confirms_sojourn() {
    // 1. The sojourn floor: with one slice per tenant per period (the PR-3
    //    planner), tenant 0's worst-case sojourn is bounded below by a
    //    full period plus a batch — its single slice sees both vgg16
    //    blocks in one gap, whatever the composition. Interleaving its
    //    quanta over k=2 sub-slices places one between the blocks
    //    (A B A C), roughly halving the gap. The k=2 search subsumes every
    //    k=1 plan, so its floor can only be lower — assert it is
    //    *strictly* lower.
    let k1 = slo_sharder(1, None).search().unwrap();
    let k2 = slo_sharder(2, None).search().unwrap();
    let l1 = min_latency(&k1, 0);
    let l2 = min_latency(&k2, 0);
    assert!(l1.is_finite() && l2.is_finite());
    assert!(
        l2 < l1 * 0.99,
        "interleaving must strictly tighten the sojourn floor ({l2} vs {l1})"
    );

    // 2. An SLO between the two floors: infeasible for the PR-3 planner...
    let slo = 0.5 * (l1 + l2);
    let err = slo_sharder(1, Some(slo)).search();
    assert!(
        err.is_err(),
        "an SLO below the k=1 sojourn floor must make the k=1 regime infeasible"
    );
    // ...admissible with interleaving.
    let r = slo_sharder(2, Some(slo)).search().unwrap();
    assert!(!r.plans.is_empty());
    for p in &r.plans {
        assert!(
            p.latency_s[0] <= slo,
            "admitted plan violates the SLO: {} > {slo}",
            p.latency_s[0]
        );
    }
    // The plan that achieves the floor really is interleaved.
    let best = r
        .plans
        .iter()
        .min_by(|a, b| a.latency_s[0].total_cmp(&b.latency_s[0]))
        .unwrap();
    let Regime::Temporal(info) = &best.regime else {
        panic!("temporal-only search produced a spatial plan")
    };
    assert!(
        info.interleave[0] >= 2,
        "the SLO-admitting plan must interleave tenant 0 (k = {:?})",
        info.interleave
    );
    assert!(
        info.slices.iter().filter(|s| s.tenant == 0).count() >= 2,
        "tenant 0 must hold several sub-slices per period"
    );

    // 3. Execute the chosen schedule: the measured worst-case sojourn must
    //    confirm the analytic bound within 5% (and never exceed it — the
    //    analytic side over-approximates makespans and under-credits
    //    drains by construction).
    let refs: Vec<&Allocation> = best.tenants.iter().map(|t| t.alloc.as_ref()).collect();
    let ts = sim::engines::simulate_schedule(&refs, &info.schedule_slices(), true);
    assert_eq!(
        ts.period_cycles, info.period_cycles,
        "exact in-window admission must not stretch the executed period"
    );
    for t in 0..3 {
        let analytic = info.latency_cycles[t];
        let measured = ts.worst_sojourn[t];
        assert!(
            measured <= analytic,
            "tenant {t}: measured sojourn {measured} exceeds the analytic bound {analytic}"
        );
        let rel = (analytic - measured) as f64 / analytic as f64;
        assert!(
            rel <= 0.05,
            "tenant {t}: measured sojourn {measured} vs analytic {analytic} ({:.2}% apart)",
            rel * 100.0
        );
        // And the executed per-tenant rate matches the analytic schedule.
        let fps_rel = (ts.tenant_fps[t] - best.fps[t]).abs() / best.fps[t];
        assert!(fps_rel <= 0.01, "tenant {t}: fps {} vs {}", ts.tenant_fps[t], best.fps[t]);
    }
}

#[test]
fn interleaved_plans_trade_throughput_for_latency_on_the_frontier() {
    // The latency axis is what keeps interleaved plans alive: k=2 pays
    // extra per-slice refills (≤ fps, uncapped slices make that cost
    // real) but cuts the start-to-start gap (≤ latency). Both directions
    // must survive the merged frontier.
    let r = Sharder {
        steps: 4,
        schedule: ScheduleMode::Temporal,
        max_interleave: 2,
        max_period_s: 0.1,
        calib_frames: 8,
        ..Sharder::new(
            zc706(),
            vec![
                Tenant::new(zoo::lenet(), QuantMode::W8A8),
                Tenant::new(zoo::lenet(), QuantMode::W8A8),
            ],
        )
    }
    .search()
    .unwrap();
    let whole = r
        .frontier
        .iter()
        .map(|&i| &r.plans[i])
        .filter_map(|p| match &p.regime {
            Regime::Temporal(info) if info.interleave.iter().all(|&k| k == 1) => Some(p),
            _ => None,
        })
        .count();
    let interleaved = r
        .frontier
        .iter()
        .map(|&i| &r.plans[i])
        .filter_map(|p| match &p.regime {
            Regime::Temporal(info) if info.interleave.iter().any(|&k| k > 1) => Some(p),
            _ => None,
        })
        .count();
    assert!(whole > 0, "whole-slice plans must survive the frontier (fps axis)");
    assert!(
        interleaved > 0,
        "interleaved plans must survive the frontier (latency axis)"
    );
}

// ---------------------------------------------------------------------------
// Static-region overlay
// ---------------------------------------------------------------------------

#[test]
fn overlay_two_identical_tenants_half_solo_fps_zero_reconfig_dead_cycles() {
    // Acceptance pin: two identical tenants sharing one superset datapath
    // switch for free (weight re-streaming only, billed through the DES's
    // group-0 weight service), so with a long period each tenant
    // approaches exactly half the solo rate — and no schedule slice
    // charges a single reconfiguration dead cycle.
    let mode = QuantMode::W16A16;
    let net = zoo::zf();
    let sharder = Sharder {
        steps: 2,
        schedule: ScheduleMode::Overlay,
        // A long period amortizes the per-slice refill, so the half-solo
        // bracket below is insensitive to the (calibrated) fill size.
        max_period_s: 1.0,
        calib_frames: 12,
        sim_frames: 1,
        ..Sharder::new(
            zc706(),
            vec![Tenant::new(net.clone(), mode), Tenant::new(net.clone(), mode)],
        )
    };
    let result = sharder.search().unwrap();
    let plan = &result.plans[result.best_min];
    let Regime::Temporal(info) = &plan.regime else {
        panic!("overlay search produced a spatial plan")
    };
    assert!(info.overlay);
    assert_eq!(info.reconfig_cycles, vec![0, 0]);
    assert!(info.slices.iter().all(|s| s.reconfig_cycles == 0 && s.overlap_cycles == 0));
    assert_eq!(plan.fps[0].to_bits(), plan.fps[1].to_bits());

    // Half-solo bracket from an independent calibration: the long period
    // amortizes the per-slice refill, so the effective rate sits just
    // below half the solo steady rate — never above it.
    let freq = zc706().freq_hz;
    let solo = FlexAllocator::default().allocate(&net, &zc706(), mode).unwrap();
    let cal = sim::simulate(&solo, 32);
    let beat_max = cal.frame_done.windows(2).map(|w| w[1] - w[0]).max().unwrap() as f64;
    let half_solo = 0.5 * freq / beat_max;
    assert!(
        plan.fps[0] <= half_solo * 1.02,
        "overlay cannot beat half the solo rate ({} > {half_solo})",
        plan.fps[0]
    );
    assert!(
        plan.fps[0] >= half_solo * 0.9,
        "zero-reconfig switches should amortize to near half solo \
         ({} < 0.9 × {half_solo})",
        plan.fps[0]
    );

    // The executed schedule confirms: zero reconfiguration dead cycles,
    // per-tenant fps within 1% of the analytic schedule.
    let sims = plan.sim.as_ref().expect("sim_frames > 0 validates the frontier");
    for (t, s) in sims.iter().enumerate() {
        let rel = (s.fps - plan.fps[t]).abs() / plan.fps[t];
        assert!(rel <= 0.01, "tenant {t}: {} vs {} fps", s.fps, plan.fps[t]);
    }
    let refs: Vec<&Allocation> = plan.tenants.iter().map(|t| t.alloc.as_ref()).collect();
    let ts = sim::engines::simulate_schedule(&refs, &info.schedule_slices(), true);
    assert!(ts.slices.iter().all(|s| s.reconfig_cycles == 0));
}

#[test]
fn auto_mode_merges_all_three_regimes() {
    let sharder = Sharder {
        steps: 4,
        schedule: ScheduleMode::Auto,
        max_period_s: 0.1,
        ..Sharder::new(
            zc706(),
            vec![
                Tenant::new(zoo::lenet(), QuantMode::W8A8),
                Tenant::new(zoo::tinycnn(), QuantMode::W8A8),
            ],
        )
    };
    let r = sharder.search().unwrap();
    let count = |label: &str| r.plans.iter().filter(|p| p.regime.label() == label).count();
    assert!(count("spatial") > 0, "auto must enumerate spatial splits");
    assert!(count("temporal") > 0, "auto must enumerate temporal schedules");
    assert!(count("overlay") > 0, "auto must enumerate overlay schedules");
    // Overlay plans of a given shape dominate-or-tie the reconfiguring
    // plans of the same shape, so the best overlay min-fps is at least the
    // best temporal one.
    let best = |label: &str| {
        r.plans
            .iter()
            .filter(|p| p.regime.label() == label)
            .map(|p| p.min_fps)
            .fold(f64::NEG_INFINITY, f64::max)
    };
    assert!(best("overlay") >= best("temporal") - 1e-9);
}
