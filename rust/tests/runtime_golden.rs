//! Integration: the runtime execution contract, end to end.
//!
//! The default tests run the deterministic in-process
//! [`flexipipe::runtime::SimBackend`] — the quantized reference operators
//! with seeded weights — so the backend contract (batch variants agree,
//! inputs validated, outputs reproducible) is exercised without artifacts.
//! The original PJRT↔Python-oracle bit-exactness tests are kept as
//! `#[ignore]`d extras: run `cargo test -- --ignored` after
//! `make artifacts` with real xla bindings.

use flexipipe::model::zoo;
use flexipipe::runtime::{default_artifact_dir, Backend, Manifest, Runtime, SimBackend};
use flexipipe::util::prop::Rng;

fn frames(elems: usize, n: usize, seed: u64) -> Vec<i8> {
    let mut rng = Rng::new(seed);
    (0..elems * n).map(|_| rng.range(-128, 127) as i8).collect()
}

#[test]
fn sim_batch_variants_agree_with_each_other() {
    // The same frame through b1 and b8 variants must give the same answer
    // (batching is a serving optimization, never a numerics change).
    for net in [zoo::tinycnn(), zoo::lenet(), zoo::vgg_micro()] {
        let be = SimBackend::new(&net, &[1, 8]).unwrap();
        let elems = be.frame_elems();
        let oe = be.out_elems();
        let input = frames(elems, 8, 42);
        let big = be
            .execute_i8(&be.variant_name(8), &input)
            .unwrap();
        for f in 0..8 {
            let small = be
                .execute_i8(&be.variant_name(1), &input[f * elems..(f + 1) * elems])
                .unwrap();
            assert_eq!(
                small,
                &big[f * oe..(f + 1) * oe],
                "{}: batch-1 vs batch-8 disagree on frame {f}",
                net.name
            );
        }
    }
}

#[test]
fn sim_backend_is_reproducible_across_instances() {
    // The "golden" contract of the sim path: weights are a pure function
    // of the net name, so independent instances are bit-identical oracles.
    let net = zoo::vgg_micro();
    let a = SimBackend::new(&net, &[2]).unwrap();
    let b = SimBackend::new(&net, &[2]).unwrap();
    let input = frames(a.frame_elems(), 2, 7);
    assert_eq!(
        a.execute_i8(&a.variant_name(2), &input).unwrap(),
        b.execute_i8(&b.variant_name(2), &input).unwrap()
    );
}

#[test]
fn sim_execute_matches_forward_frame() {
    let net = zoo::tinycnn();
    let be = SimBackend::new(&net, &[1]).unwrap();
    let input = frames(be.frame_elems(), 1, 3);
    assert_eq!(
        be.execute_i8(&be.variant_name(1), &input).unwrap(),
        be.forward_frame(&input).unwrap()
    );
}

#[test]
fn sim_execute_rejects_wrong_input_size() {
    let be = SimBackend::new(&zoo::tinycnn(), &[1]).unwrap();
    let err = be.execute_i8(&be.variant_name(1), &[0i8; 3]).unwrap_err();
    assert!(err.to_string().contains("elements"));
}

// ---------------------------------------------------------------------------
// PJRT ↔ Python-oracle bit-exactness: artifact-gated extras.
// ---------------------------------------------------------------------------

fn pjrt_runtime() -> Runtime {
    Runtime::load(default_artifact_dir()).expect("run `make artifacts` first")
}

#[test]
#[ignore = "needs `make artifacts` + real PJRT bindings"]
fn every_artifact_matches_the_python_oracle_bit_exactly() {
    let rt = pjrt_runtime();
    let artifacts = rt.manifest().artifacts.clone();
    assert!(!artifacts.is_empty());
    for a in &artifacts {
        if a.bits != 8 {
            continue;
        }
        let input = rt.golden_inputs(&a.name).unwrap();
        let golden = rt.golden_outputs(&a.name).unwrap();
        let elems = a.golden.frame_elems;
        let oe = a.golden.out_elems;
        let mut frame = 0;
        while frame + a.batch <= a.golden.frames {
            let out = rt
                .execute_i8(&a.name, &input[frame * elems..(frame + a.batch) * elems])
                .unwrap();
            assert_eq!(
                out,
                &golden[frame * oe..(frame + a.batch) * oe],
                "{}: frames {}..{} diverge from the oracle",
                a.name,
                frame,
                frame + a.batch
            );
            frame += a.batch;
        }
    }
}

#[test]
#[ignore = "needs `make artifacts` + real PJRT bindings"]
fn pjrt_batch_variants_agree_with_each_other() {
    let rt = pjrt_runtime();
    let v = rt.manifest().variants("tinycnn", 8);
    if v.len() < 2 {
        return;
    }
    let (small, big) = (v[0].clone(), v[v.len() - 1].clone());
    let input = rt.golden_inputs(&small.name).unwrap();
    let elems = small.golden.frame_elems;
    let oe = small.golden.out_elems;

    // big batch: first `batch` golden frames at once
    let big_out = rt
        .execute_i8(&big.name, &input[..big.batch * elems])
        .unwrap();
    for f in 0..big.batch.min(small.golden.frames) {
        let small_out = rt
            .execute_i8(&small.name, &input[f * elems..(f + 1) * elems])
            .unwrap();
        assert_eq!(
            small_out,
            &big_out[f * oe..(f + 1) * oe],
            "batch-1 vs batch-{} disagree on frame {f}",
            big.batch
        );
    }
}

#[test]
#[ignore = "needs `make artifacts` + real PJRT bindings"]
fn pjrt_execute_rejects_wrong_input_size() {
    let rt = pjrt_runtime();
    let a = rt.manifest().artifacts[0].clone();
    let err = rt.execute_i8(&a.name, &[0i8; 3]).unwrap_err();
    assert!(err.to_string().contains("elements"));
}

#[test]
fn manifest_hashes_match_files() {
    // The manifest's recorded sha256 must match the artifact actually on
    // disk (stale-artifact detection). PJRT-free, so it runs by default
    // whenever artifacts exist and passes quietly when they don't — a
    // developer with a stale `artifacts/` gets the hash diagnosis instead
    // of a baffling bit-exactness failure.
    let dir = default_artifact_dir();
    if !dir.join("manifest.json").exists() {
        return;
    }
    let manifest = Manifest::load(dir.join("manifest.json")).unwrap();
    for a in &manifest.artifacts {
        let text = std::fs::read_to_string(dir.join(&a.hlo)).unwrap();
        let digest = sha256_hex(text.as_bytes());
        assert_eq!(
            digest, a.hlo_sha256,
            "{}: artifact on disk does not match manifest (stale build?)",
            a.name
        );
    }
}

/// Minimal SHA-256 (no crypto crates in the offline vendor set; this is the
/// standard FIPS 180-4 compression, tested against the manifest itself).
fn sha256_hex(data: &[u8]) -> String {
    const K: [u32; 64] = [
        0x428a2f98, 0x71374491, 0xb5c0fbcf, 0xe9b5dba5, 0x3956c25b, 0x59f111f1, 0x923f82a4,
        0xab1c5ed5, 0xd807aa98, 0x12835b01, 0x243185be, 0x550c7dc3, 0x72be5d74, 0x80deb1fe,
        0x9bdc06a7, 0xc19bf174, 0xe49b69c1, 0xefbe4786, 0x0fc19dc6, 0x240ca1cc, 0x2de92c6f,
        0x4a7484aa, 0x5cb0a9dc, 0x76f988da, 0x983e5152, 0xa831c66d, 0xb00327c8, 0xbf597fc7,
        0xc6e00bf3, 0xd5a79147, 0x06ca6351, 0x14292967, 0x27b70a85, 0x2e1b2138, 0x4d2c6dfc,
        0x53380d13, 0x650a7354, 0x766a0abb, 0x81c2c92e, 0x92722c85, 0xa2bfe8a1, 0xa81a664b,
        0xc24b8b70, 0xc76c51a3, 0xd192e819, 0xd6990624, 0xf40e3585, 0x106aa070, 0x19a4c116,
        0x1e376c08, 0x2748774c, 0x34b0bcb5, 0x391c0cb3, 0x4ed8aa4a, 0x5b9cca4f, 0x682e6ff3,
        0x748f82ee, 0x78a5636f, 0x84c87814, 0x8cc70208, 0x90befffa, 0xa4506ceb, 0xbef9a3f7,
        0xc67178f2,
    ];
    let mut h: [u32; 8] = [
        0x6a09e667, 0xbb67ae85, 0x3c6ef372, 0xa54ff53a, 0x510e527f, 0x9b05688c, 0x1f83d9ab,
        0x5be0cd19,
    ];
    let mut msg = data.to_vec();
    let bitlen = (data.len() as u64) * 8;
    msg.push(0x80);
    while msg.len() % 64 != 56 {
        msg.push(0);
    }
    msg.extend_from_slice(&bitlen.to_be_bytes());
    for chunk in msg.chunks(64) {
        let mut w = [0u32; 64];
        for i in 0..16 {
            w[i] = u32::from_be_bytes(chunk[i * 4..i * 4 + 4].try_into().unwrap());
        }
        for i in 16..64 {
            let s0 = w[i - 15].rotate_right(7) ^ w[i - 15].rotate_right(18) ^ (w[i - 15] >> 3);
            let s1 = w[i - 2].rotate_right(17) ^ w[i - 2].rotate_right(19) ^ (w[i - 2] >> 10);
            w[i] = w[i - 16]
                .wrapping_add(s0)
                .wrapping_add(w[i - 7])
                .wrapping_add(s1);
        }
        let (mut a, mut b, mut c, mut d, mut e, mut f, mut g, mut hh) =
            (h[0], h[1], h[2], h[3], h[4], h[5], h[6], h[7]);
        for i in 0..64 {
            let s1 = e.rotate_right(6) ^ e.rotate_right(11) ^ e.rotate_right(25);
            let ch = (e & f) ^ ((!e) & g);
            let t1 = hh
                .wrapping_add(s1)
                .wrapping_add(ch)
                .wrapping_add(K[i])
                .wrapping_add(w[i]);
            let s0 = a.rotate_right(2) ^ a.rotate_right(13) ^ a.rotate_right(22);
            let maj = (a & b) ^ (a & c) ^ (b & c);
            let t2 = s0.wrapping_add(maj);
            hh = g;
            g = f;
            f = e;
            e = d.wrapping_add(t1);
            d = c;
            c = b;
            b = a;
            a = t1.wrapping_add(t2);
        }
        h[0] = h[0].wrapping_add(a);
        h[1] = h[1].wrapping_add(b);
        h[2] = h[2].wrapping_add(c);
        h[3] = h[3].wrapping_add(d);
        h[4] = h[4].wrapping_add(e);
        h[5] = h[5].wrapping_add(f);
        h[6] = h[6].wrapping_add(g);
        h[7] = h[7].wrapping_add(hh);
    }
    h.iter().map(|x| format!("{x:08x}")).collect()
}

#[test]
fn sha256_known_vector() {
    assert_eq!(
        sha256_hex(b"abc"),
        "ba7816bf8f01cfea414140de5dae2223b00361a396177a9cb410ff61f20015ad"
    );
}
