//! Acceptance suite for the ingestion layer, pinned against the
//! checked-in plan + trace artifacts:
//!
//! - `serve --plan … --trace …` is byte-deterministic across process
//!   runs (the property CI enforces with cmp(1)),
//! - under the sub-saturation checked-in trace, every tenant's measured
//!   p100 sojourn is ≤ the plan's analytic `worst_sojourn`,
//! - once offered load exceeds the plan's admitted rate, admission
//!   rejects with the typed queue-full reason instead of queueing
//!   unboundedly,
//! - the same arrival streams replayed through the DES's closed-loop
//!   engine (`sim::engines::replay_arrivals`, executed timeline) respect
//!   the same bound — the planned-timeline model cross-validated,
//! - `trace gen` authors loadable specs and enforces duration suffixes,
//! - the live `IngestService` applies backpressure end-to-end.

use flexipipe::coordinator::BatchPolicy;
use flexipipe::ingest::{
    self, ArrivalProcess, IngestPolicy, IngestService, RejectReason, TenantTrace, TraceSpec,
};
use flexipipe::plan::DeploymentPlan;
use flexipipe::shard::Regime;
use flexipipe::sim;
use std::path::PathBuf;
use std::process::Command;
use std::time::Duration;

fn bin() -> &'static str {
    env!("CARGO_BIN_EXE_flexipipe")
}

fn plan_fixture() -> &'static str {
    concat!(
        env!("CARGO_MANIFEST_DIR"),
        "/../examples/plans/vgg16_alexnet_zc706.json"
    )
}

fn trace_fixture() -> &'static str {
    concat!(
        env!("CARGO_MANIFEST_DIR"),
        "/../examples/traces/diurnal_vgg16.json"
    )
}

fn tmp_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("flexipipe_ingest").join(name);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn run_ok(args: &[&str]) -> String {
    let out = Command::new(bin()).args(args).output().unwrap();
    assert!(
        out.status.success(),
        "flexipipe {args:?} failed:\nstdout: {}\nstderr: {}",
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr)
    );
    String::from_utf8_lossy(&out.stdout).into_owned()
}

#[test]
fn checked_in_trace_respects_the_analytic_sojourn_bound() {
    // The acceptance property: sub-saturation offered load (0.8 / 1.5
    // fps vs plan capacity 2 / 4 fps), slice-admissible queue depth →
    // every tenant's worst measured sojourn within the plan's analytic
    // worst_sojourn.
    let plan = DeploymentPlan::load(plan_fixture()).unwrap();
    let spec = TraceSpec::load(trace_fixture()).unwrap();
    let report = ingest::serve_trace(&plan, &spec).unwrap();
    assert_eq!(report.tenants.len(), 2);
    for t in &report.tenants {
        assert!(t.offered > 0, "{}: trace generated no arrivals", t.net);
        assert!(t.admitted > 0, "{}: nothing admitted", t.net);
        assert_eq!(t.offered, t.admitted + t.rejected_full, "{}", t.net);
        let bound = t
            .worst_sojourn_cycles
            .expect("temporal plan carries an analytic bound");
        assert!(
            t.p100_cycles <= bound,
            "{}: p100 {} cycles exceeds analytic worst_sojourn {bound}",
            t.net,
            t.p100_cycles
        );
        assert_eq!(t.within_bound, Some(true), "{}", t.net);
        // Quantiles are monotone and p100 dominates the tail estimates'
        // underlying samples.
        assert!(t.p50_cycles <= t.p99_cycles && t.p99_cycles <= t.p999_cycles);
    }
    // Library-level determinism: same inputs, byte-identical report.
    let again = ingest::serve_trace(&plan, &spec).unwrap();
    assert_eq!(
        report.to_json().to_pretty(),
        again.to_json().to_pretty(),
        "serve_trace must be deterministic"
    );
}

#[test]
fn serve_trace_cli_is_byte_deterministic_across_runs() {
    // Two separate processes, identical stdout bytes — the CI cmp(1)
    // property — and stdout is pure machine-readable JSON.
    let args = ["serve", "--plan", plan_fixture(), "--trace", trace_fixture()];
    let first = run_ok(&args);
    let second = run_ok(&args);
    assert_eq!(first, second, "trace replay must be byte-deterministic");
    let v = flexipipe::util::json::parse(first.trim()).unwrap();
    assert_eq!(v.req("seed").unwrap().as_f64(), Some(2026.0));
    let tenants = v.req("tenants").unwrap().as_arr().unwrap();
    assert_eq!(tenants.len(), 2);
    for t in tenants {
        assert!(t.bool_field("within_bound").unwrap(), "{first}");
        assert_eq!(
            t.str_field("reject_reason").unwrap(),
            "queue-full",
            "rejections must carry the typed reason"
        );
        let p100 = t.f64_field("p100_cycles").unwrap();
        let bound = t.f64_field("worst_sojourn_cycles").unwrap();
        assert!(p100 <= bound, "{first}");
    }
}

#[test]
fn oversaturated_trace_is_rejected_with_typed_backpressure() {
    // Offered 50 fps ≫ the plan's 2 fps vgg16 capacity: the bounded
    // queue must shed most arrivals as queue-full — and the sojourns of
    // what IS admitted still respect the bound (that is the point of
    // admission control: overload degrades availability, not latency).
    let plan = DeploymentPlan::load(plan_fixture()).unwrap();
    let spec = TraceSpec {
        seed: 7,
        duration_s: 5.0,
        queue_capacity: 0,
        tenants: vec![TenantTrace {
            tenant: "vgg16".into(),
            process: ArrivalProcess::Poisson { rate_fps: 50.0 },
        }],
    };
    let report = ingest::serve_trace(&plan, &spec).unwrap();
    let t = &report.tenants[0];
    assert_eq!(t.net, "vgg16");
    assert!(
        t.rejected_full > t.admitted,
        "50 fps against a 2 fps plan must mostly reject (admitted {}, rejected {})",
        t.admitted,
        t.rejected_full
    );
    assert_eq!(t.within_bound, Some(true), "admitted work stays in-bound");
}

#[test]
fn replayed_arrivals_through_the_des_respect_the_same_bound() {
    // Cross-validation: inject the same arrival streams into the
    // *executed* schedule timeline (closed-loop DES replay) instead of
    // the planned one. Same admission depths → the analytic bound must
    // hold there too.
    let plan = DeploymentPlan::load(plan_fixture()).unwrap();
    let spec = TraceSpec::load(trace_fixture()).unwrap();
    let Regime::Temporal(info) = &plan.regime else {
        panic!("checked-in plan is temporal");
    };
    let allocs = plan.instantiate().unwrap();
    let refs: Vec<&flexipipe::alloc::Allocation> = allocs.iter().collect();
    let executed = sim::engines::simulate_schedule(&refs, &info.schedule_slices(), true);
    let arrivals = spec.arrivals(plan.board.freq_hz).unwrap();
    let caps: Vec<usize> = (0..plan.tenants.len())
        .map(|t| info.slice_admissible_depth(t).unwrap_or(1))
        .collect();
    let replayed = sim::engines::replay_arrivals(&executed, &arrivals, &caps);
    let bounds = plan.worst_sojourn_cycles().unwrap();
    for (t, r) in replayed.iter().enumerate() {
        assert!(!r.sojourns.is_empty(), "tenant {t} served nothing");
        let p100 = *r.sojourns.iter().max().unwrap();
        assert!(
            p100 <= bounds[t],
            "tenant {t}: executed-timeline p100 {p100} exceeds analytic bound {}",
            bounds[t]
        );
    }
}

#[test]
fn trace_gen_cli_authors_loadable_specs() {
    let dir = tmp_dir("gen");
    let out = dir.join("trace.json");
    let path = out.to_str().unwrap();
    run_ok(&[
        "trace",
        "gen",
        "--arrivals",
        "vgg16=diurnal:0.4:1.2:5s,alexnet=poisson:1.5",
        "--seed",
        "2026",
        "--duration",
        "20s",
        "--out",
        path,
    ]);
    let spec = TraceSpec::load(path).unwrap();
    assert_eq!(spec.seed, 2026);
    assert_eq!(spec.duration_s, 20.0);
    assert_eq!(spec.tenants.len(), 2);
    // The authored spec is exactly the checked-in fixture (which was
    // generated by this command — regeneration stays in sync).
    let fixture = TraceSpec::load(trace_fixture()).unwrap();
    assert_eq!(spec, fixture);

    // Unit rigor: a bare number is not a duration, and the error names
    // the accepted suffixes.
    let bad = Command::new(bin())
        .args(["trace", "gen", "--arrivals", "vgg16=poisson:1", "--duration", "20"])
        .output()
        .unwrap();
    assert!(!bad.status.success());
    let err = String::from_utf8_lossy(&bad.stderr).into_owned();
    assert!(err.contains("s, ms, us, m, or h"), "{err}");
}

#[test]
fn live_ingest_service_applies_backpressure_end_to_end() {
    use flexipipe::board::zedboard;
    use flexipipe::model::zoo;
    use flexipipe::plan::{Planner, Workload};
    use flexipipe::quant::QuantMode;

    // An 8-bit plan the live SimBackend can serve.
    let w = Workload::new(QuantMode::W8A8).tenant(zoo::tinycnn()).tenant(zoo::lenet());
    let set = Planner::on(zedboard()).steps(8).plan(&w).unwrap();
    let plan = set.plans[set.best].clone();

    // One waiting slot, one in-flight request, and a slow link: a burst
    // of three submissions must trip queue-full on at least one.
    let batch = BatchPolicy {
        link_latency: Duration::from_millis(50),
        ..BatchPolicy::default()
    };
    let policy = IngestPolicy {
        queue_capacity: 1,
        max_inflight: 1,
        ..IngestPolicy::default()
    };
    let svc = IngestService::start(&plan, batch, policy).unwrap();
    assert_eq!(svc.len(), 2);

    let (c, h, wd) = plan.tenants[0].net.input;
    let frame = vec![0i8; c * h * wd];
    let mut accepted = Vec::new();
    let mut rejected = 0u64;
    for _ in 0..3 {
        match svc.submit(0, frame.clone(), 0) {
            Ok(rx) => accepted.push(rx),
            Err(RejectReason::QueueFull { capacity, .. }) => {
                assert_eq!(capacity, 1);
                rejected += 1;
            }
            Err(other) => panic!("unexpected rejection: {other}"),
        }
    }
    assert!(rejected >= 1, "burst of 3 into capacity 1 must shed");
    assert!(!accepted.is_empty(), "admission must not shed everything");
    for rx in accepted {
        let out = rx
            .recv()
            .expect("dispatcher delivers a result")
            .expect("backend serves the frame");
        assert!(!out.is_empty());
    }

    // Introspection reflects the outcome; the untouched tenant is idle.
    let status = svc.status();
    assert_eq!(status[0].tenant, "tinycnn");
    assert_eq!(status[0].rejected_full, rejected);
    assert_eq!(status[0].admitted + rejected, 3);
    assert_eq!(status[0].completed, status[0].admitted);
    assert_eq!(status[1].admitted, 0);
    assert!(svc.histogram(0).count() >= 1, "completions are recorded");

    let final_status = svc.shutdown();
    assert_eq!(final_status.len(), 2);
    assert_eq!(final_status[0].depth, 0, "shutdown drains the queue");
}

/// An 8-bit two-tenant plan the live `SimBackend` can serve — shared by
/// the deadline/cancel/apply tests below.
fn eight_bit_plan() -> DeploymentPlan {
    use flexipipe::board::zedboard;
    use flexipipe::model::zoo;
    use flexipipe::plan::{Planner, Workload};
    use flexipipe::quant::QuantMode;
    let w = Workload::new(QuantMode::W8A8).tenant(zoo::tinycnn()).tenant(zoo::lenet());
    let set = Planner::on(zedboard()).steps(8).plan(&w).unwrap();
    set.plans[set.best].clone()
}

fn frame_for(plan: &DeploymentPlan, idx: usize) -> Vec<i8> {
    let (c, h, w) = plan.tenants[idx].net.input;
    vec![0i8; c * h * w]
}

#[test]
fn expired_deadlines_are_never_dispatched() {
    use std::time::Instant;
    // The acceptance property: a deadline at or before submission time
    // means served count 0 and every rejection typed DeadlineExpired —
    // checked before queue-full/shedding so the reason is never
    // coincidental.
    let plan = eight_bit_plan();
    let svc = IngestService::start(&plan, BatchPolicy::default(), IngestPolicy::default()).unwrap();
    let frame = frame_for(&plan, 0);
    let now = Instant::now();
    let past = now.checked_sub(Duration::from_millis(5)).unwrap_or(now);
    let n = 20;
    for i in 0..n {
        match svc.submit_with(0, frame.clone(), (i % 3) as u8, Some(past)) {
            Err(RejectReason::DeadlineExpired { .. }) => {}
            other => panic!("dead-on-arrival request {i} must report DeadlineExpired: {other:?}"),
        }
    }
    let status = svc.status();
    assert_eq!(status[0].expired, n, "every expiry is counted");
    assert_eq!(status[0].admitted, 0, "expired work is never queued");
    let final_status = svc.shutdown();
    assert_eq!(final_status[0].completed, 0, "expired work is never served");
}

#[test]
fn deadlines_expiring_in_queue_are_dropped_at_dispatch() {
    use std::time::Instant;
    // A deadline that is still in the future at admission but passes
    // while the request waits behind a slow in-flight frame is enforced
    // by the dispatcher at pop time.
    let plan = eight_bit_plan();
    let batch = BatchPolicy {
        link_latency: Duration::from_millis(500),
        ..BatchPolicy::default()
    };
    let policy = IngestPolicy {
        queue_capacity: 4,
        max_inflight: 1,
        ..IngestPolicy::default()
    };
    let svc = IngestService::start(&plan, batch, policy).unwrap();
    let frame = frame_for(&plan, 0);
    // Occupy the single in-flight slot for ≥500 ms…
    let rx_a = svc.submit(0, frame.clone(), 0).unwrap();
    // …then queue a request whose deadline (50 ms) expires long before
    // the slot frees.
    let deadline = Instant::now() + Duration::from_millis(50);
    let (_, rx_b) = svc.submit_with(0, frame, 0, Some(deadline)).unwrap();
    assert!(rx_a.recv().unwrap().is_ok(), "the occupying frame is served");
    let err = rx_b
        .recv()
        .expect("dispatcher resolves the expired request's channel")
        .expect_err("an expired request must not be served");
    assert!(err.to_string().contains("deadline expired"), "{err}");
    let status = svc.shutdown();
    assert_eq!(status[0].expired, 1);
    assert_eq!(status[0].completed, 1);
}

#[test]
fn cancelled_requests_are_purged_from_the_queue() {
    let plan = eight_bit_plan();
    let batch = BatchPolicy {
        link_latency: Duration::from_millis(200),
        ..BatchPolicy::default()
    };
    let policy = IngestPolicy {
        queue_capacity: 4,
        max_inflight: 1,
        ..IngestPolicy::default()
    };
    let svc = IngestService::start(&plan, batch, policy).unwrap();
    let frame = frame_for(&plan, 0);
    let rx_a = svc.submit(0, frame.clone(), 0).unwrap();
    let (id, rx_b) = svc.submit_with(0, frame, 0, None).unwrap();
    assert!(svc.cancel(id), "a still-queued request is cancellable");
    assert!(!svc.cancel(id), "cancellation is idempotent-false");
    assert!(!svc.cancel(u64::MAX), "unknown ids are not cancellable");
    let err = rx_b
        .recv()
        .expect("cancellation resolves the response channel")
        .expect_err("a cancelled request is never served");
    assert!(err.to_string().contains("cancelled"), "{err}");
    assert!(rx_a.recv().unwrap().is_ok(), "the in-flight frame is unaffected");
    let status = svc.shutdown();
    assert_eq!(status[0].cancelled, 1);
    assert_eq!(status[0].admitted, 2);
    assert_eq!(status[0].completed, 1);
}

#[test]
fn shutdown_under_load_resolves_every_receiver() {
    // Shutdown joins the dispatcher before draining and snapshotting, so
    // every admitted request's channel resolves (served or Closed), the
    // final depth is zero, and the counters are coherent — the ordering
    // contract pinned by `IngestService::shutdown`.
    let plan = eight_bit_plan();
    let batch = BatchPolicy {
        link_latency: Duration::from_millis(50),
        ..BatchPolicy::default()
    };
    let policy = IngestPolicy {
        queue_capacity: 8,
        max_inflight: 1,
        ..IngestPolicy::default()
    };
    let svc = IngestService::start(&plan, batch, policy).unwrap();
    let frame = frame_for(&plan, 0);
    let receivers: Vec<_> = (0..6).map(|_| svc.submit(0, frame.clone(), 0).unwrap()).collect();
    let status = svc.shutdown();
    let mut served = 0u64;
    for rx in receivers {
        // The channel must hold a result even though the service is gone.
        match rx.recv().expect("shutdown resolves every admitted request") {
            Ok(out) => {
                assert!(!out.is_empty());
                served += 1;
            }
            Err(e) => assert!(e.to_string().contains("shut down"), "{e}"),
        }
    }
    assert_eq!(status[0].depth, 0, "no request is left queued");
    assert_eq!(status[0].admitted, 6);
    assert_eq!(status[0].completed, served, "counters match delivered results");
}

#[test]
fn live_apply_keeps_kept_tenants_and_fails_removed_queues() {
    let plan = eight_bit_plan();
    let batch = BatchPolicy {
        link_latency: Duration::from_millis(100),
        ..BatchPolicy::default()
    };
    let policy = IngestPolicy {
        queue_capacity: 4,
        max_inflight: 1,
        ..IngestPolicy::default()
    };
    let mut svc = IngestService::start(&plan, batch, policy).unwrap();
    let frame0 = frame_for(&plan, 0);
    let rx = svc.submit(0, frame0.clone(), 0).unwrap();
    assert!(rx.recv().unwrap().is_ok());

    // A no-op diff keeps every tenant: counters, queues, and names
    // survive the apply.
    let noop = plan.diff(&plan).unwrap();
    let report = svc.apply(&noop).unwrap();
    assert_eq!(report.kept, vec!["tinycnn".to_string(), "lenet".to_string()]);
    assert!(report.restarted.is_empty() && report.added.is_empty() && report.removed.is_empty());
    assert_eq!(svc.names(), vec!["tinycnn".to_string(), "lenet".to_string()]);
    assert_eq!(svc.status()[0].admitted, 1, "kept lanes retain their counters");

    // The service keeps serving after the swap.
    let rx = svc.submit(0, frame0, 0).unwrap();
    assert!(rx.recv().unwrap().is_ok());
    assert_eq!(svc.status()[0].admitted, 2);

    // Shrink to a solo-tinycnn plan: lenet's lane closes, and a request
    // still queued for it fails typed rather than hanging.
    let rx1 = svc.submit(1, frame_for(&plan, 1), 0).unwrap();
    let rx2 = svc.submit(1, frame_for(&plan, 1), 0).unwrap();
    // Wait until rx1 is actually in flight: the apply below pauses the
    // dispatcher, and an undispatched rx1 would drain as Closed instead
    // of being served.
    for _ in 0..500 {
        if svc.status()[1].inflight >= 1 {
            break;
        }
        std::thread::sleep(Duration::from_millis(1));
    }
    assert_eq!(svc.status()[1].inflight, 1, "rx1 must be in flight before the apply");
    let solo = {
        use flexipipe::board::zedboard;
        use flexipipe::model::zoo;
        use flexipipe::plan::{Planner, Workload};
        use flexipipe::quant::QuantMode;
        let w = Workload::new(QuantMode::W8A8).tenant(zoo::tinycnn());
        let set = Planner::on(zedboard()).steps(8).plan(&w).unwrap();
        set.plans[set.best].clone()
    };
    let shrink = svc.plan().diff(&solo).unwrap();
    let report = svc.apply(&shrink).unwrap();
    assert_eq!(report.removed, vec!["lenet".to_string()]);
    assert_eq!(svc.len(), 1);
    assert_eq!(svc.names(), vec!["tinycnn".to_string()]);
    // rx1 was in flight when the apply paused the dispatcher (which
    // joins only after in-flight work completes), so it was served; rx2
    // was still queued and fails with the typed closed reason.
    assert!(rx1.recv().unwrap().is_ok());
    let err = rx2.recv().unwrap().expect_err("queued work for a removed tenant fails");
    assert!(err.to_string().contains("shut down"), "{err}");
    let final_status = svc.shutdown();
    assert_eq!(final_status.len(), 1);
}

#[test]
fn trace_spec_fixture_roundtrips_and_rejects_future_versions() {
    let spec = TraceSpec::load(trace_fixture()).unwrap();
    let back = TraceSpec::from_json(&spec.to_json()).unwrap();
    assert_eq!(spec, back);
    let mut v = spec.to_json();
    if let flexipipe::util::json::Value::Obj(m) = &mut v {
        m.insert("version".into(), flexipipe::util::json::Value::Num(2.0));
    }
    let err = TraceSpec::from_json(&v).unwrap_err().to_string();
    assert!(
        err.contains("unsupported trace-spec version 2") && err.contains("1..=1"),
        "{err}"
    );
}
