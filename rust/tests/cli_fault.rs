//! CLI-level fault tolerance: `simulate --plan … --faults …` emits a pure
//! JSON report that is byte-identical across process runs (the
//! determinism CI re-runs it and diffs); `plan --diff` reports identical
//! plans as empty; and `replan --plan … --faults …` writes a loadable
//! failover plan with an explicit outcome document.

use flexipipe::fault::FaultPlan;
use flexipipe::plan::DeploymentPlan;
use std::path::PathBuf;
use std::process::Command;

fn bin() -> &'static str {
    env!("CARGO_BIN_EXE_flexipipe")
}

fn plan_fixture() -> &'static str {
    concat!(
        env!("CARGO_MANIFEST_DIR"),
        "/../examples/plans/vgg16_alexnet_zc706.json"
    )
}

fn fault_fixture() -> &'static str {
    concat!(
        env!("CARGO_MANIFEST_DIR"),
        "/../examples/faults/board_loss.json"
    )
}

fn tmp_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("flexipipe_cli_fault").join(name);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn run_ok(args: &[&str]) -> String {
    let out = Command::new(bin()).args(args).output().unwrap();
    assert!(
        out.status.success(),
        "flexipipe {args:?} failed:\nstdout: {}\nstderr: {}",
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr)
    );
    String::from_utf8_lossy(&out.stdout).into_owned()
}

#[test]
fn fault_simulation_is_byte_deterministic_across_runs() {
    // The same seeded scenario, two separate processes, identical bytes —
    // the property the CI determinism step enforces with cmp(1).
    let args = [
        "simulate",
        "--plan",
        plan_fixture(),
        "--faults",
        fault_fixture(),
        "--frames",
        "1",
    ];
    let first = run_ok(&args);
    let second = run_ok(&args);
    assert_eq!(first, second, "fault-injected simulation must be deterministic");
    // Pure JSON (machine-diffable): parses whole, no prose around it.
    let v = flexipipe::util::json::parse(first.trim()).unwrap();
    assert_eq!(v.req("seed").unwrap().as_f64(), Some(7.0));
    let tenants = v.req("tenants").unwrap().as_arr().unwrap();
    assert_eq!(tenants.len(), 2);
    assert_eq!(
        tenants[0].req("net").unwrap().as_str(),
        Some("vgg16"),
        "{first}"
    );
    // The checked-in scenario injects a mid-horizon loss: service is
    // truncated, not free.
    let frac = tenants[0].req("served_frac").unwrap().as_f64().unwrap();
    assert!(frac > 0.0 && frac < 1.0, "served_frac {frac}");
}

#[test]
fn plan_diff_cli_reports_identical_plans_as_empty() {
    let out = run_ok(&["plan", "--diff", plan_fixture(), plan_fixture()]);
    let v = flexipipe::util::json::parse(out.trim()).unwrap();
    assert!(v.bool_field("empty").unwrap());
    assert_eq!(v.f64_field("cost_cycles").unwrap(), 0.0);
    // Wrong arity is a usage error, not a crash.
    let bad = Command::new(bin())
        .args(["plan", "--diff", plan_fixture()])
        .output()
        .unwrap();
    assert!(!bad.status.success());
    assert!(
        String::from_utf8_lossy(&bad.stderr).contains("two plan files"),
        "{}",
        String::from_utf8_lossy(&bad.stderr)
    );
}

#[test]
fn replan_cli_writes_a_loadable_failover_plan() {
    let dir = tmp_dir("replan");
    let out_path = dir.join("failover.json");
    let stdout = run_ok(&[
        "replan",
        "--plan",
        plan_fixture(),
        "--faults",
        fault_fixture(),
        "--shard-steps",
        "4",
        "--json",
        out_path.to_str().unwrap(),
    ]);
    let v = flexipipe::util::json::parse(stdout.trim()).unwrap();
    assert!(v.bool_field("replanned").unwrap());
    assert_eq!(v.req("shed").unwrap().as_arr().unwrap().len(), 0);
    // The written plan is a loadable deployment plan on the surviving
    // board (87.5% fabric, browned-out port).
    let plan = DeploymentPlan::load(&out_path).unwrap();
    assert_eq!(plan.tenants.len(), 2);
    let faults = FaultPlan::load(fault_fixture()).unwrap();
    let incumbent = DeploymentPlan::load(plan_fixture()).unwrap();
    let surviving = faults.surviving_board(&incumbent.board);
    assert_eq!(plan.board.dsps, surviving.dsps);
    assert!((plan.board.ddr_bytes_per_sec - surviving.ddr_bytes_per_sec).abs() < 1.0);
}
