//! Fault-injection properties: seeded fault plans round-trip their JSON
//! format byte-stably and reject unknown versions with the full context;
//! a neutral fault plan reproduces the healthy simulation bit-for-bit;
//! each injected fault moves the report the way its physics says it must
//! (DDR brownout and reconfiguration overruns cut throughput, board loss
//! truncates effective service); and the same seed always produces the
//! same report (the determinism CI diffs across process runs).

use flexipipe::board::{zc706, zedboard};
use flexipipe::fault::{BoardLoss, ErrorBurst, FaultPlan, ReconfigFault};
use flexipipe::model::zoo;
use flexipipe::plan::{DeploymentPlan, Planner, Workload};
use flexipipe::quant::QuantMode;
use flexipipe::shard::{Regime, ScheduleMode};
use flexipipe::sim::{Simulate, Simulator};
use flexipipe::util::json;
use flexipipe::util::prop::check;

fn spatial_plan() -> DeploymentPlan {
    let set = Planner::on(zedboard())
        .steps(8)
        .plan(
            &Workload::new(QuantMode::W8A8)
                .tenant(zoo::tinycnn())
                .tenant(zoo::lenet()),
        )
        .unwrap();
    set.plans[set.best].clone()
}

/// A time-multiplexed plan whose schedule pays real reconfiguration
/// cycles — the surface the reconfiguration faults rewrite.
fn temporal_plan() -> DeploymentPlan {
    let set = Planner::on(zc706())
        .steps(4)
        .schedule(ScheduleMode::Temporal)
        .max_period(0.1)
        .plan(
            &Workload::new(QuantMode::W8A8)
                .tenant(zoo::tinycnn())
                .tenant(zoo::lenet()),
        )
        .unwrap();
    set.plans
        .iter()
        .find(|p| match &p.regime {
            Regime::Temporal(i) => {
                i.period_cycles > 0 && i.reconfig_cycles.iter().any(|&c| c > 0)
            }
            _ => false,
        })
        .expect("temporal search must yield a reconfiguring schedule")
        .clone()
}

fn full_fault() -> FaultPlan {
    FaultPlan {
        seed: 42,
        board_loss: Some(BoardLoss {
            at_s: 0.25,
            survive_frac: 0.875,
        }),
        ddr_factor: Some(0.9),
        reconfig: Some(ReconfigFault {
            overrun_factor: 2.0,
            failure_prob: 0.5,
        }),
        backend_errors: Some(ErrorBurst {
            start: 1,
            length: 2,
        }),
    }
}

#[test]
fn fault_plan_file_round_trips_and_load_errors_carry_the_path() {
    let dir = std::env::temp_dir().join("flexipipe_fault_props");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("faults.json");
    let plan = full_fault();
    plan.save(&path).unwrap();
    let back = FaultPlan::load(&path).unwrap();
    assert_eq!(plan, back);
    assert_eq!(
        plan.to_json().to_pretty(),
        back.to_json().to_pretty(),
        "file round trip must be byte-stable"
    );

    // An unknown version is refused with the version found, the supported
    // range, and (through load) the offending path — never half-read.
    let bumped = plan
        .to_json()
        .to_pretty()
        .replacen("\"version\": 1", "\"version\": 9", 1);
    let bad = dir.join("future.json");
    std::fs::write(&bad, &bumped).unwrap();
    let err = FaultPlan::load(&bad).unwrap_err().to_string();
    assert!(err.contains("version 9"), "{err}");
    assert!(err.contains("1..=1"), "{err}");
    assert!(err.contains(bad.display().to_string().as_str()), "{err}");
}

#[test]
fn prop_random_fault_plans_round_trip_byte_stably() {
    check("fault-plan-roundtrip", 32, |rng| {
        let f = FaultPlan {
            // Seeds stay below 2^53 so the JSON number representation is
            // exact (the format stores one numeric type).
            seed: rng.urange(0, 1 << 30) as u64,
            board_loss: rng.flip().then(|| BoardLoss {
                at_s: rng.urange(0, 1000) as f64 / 100.0,
                survive_frac: rng.urange(1, 100) as f64 / 100.0,
            }),
            ddr_factor: rng.flip().then(|| rng.urange(1, 100) as f64 / 100.0),
            reconfig: rng.flip().then(|| ReconfigFault {
                overrun_factor: 1.0 + rng.urange(0, 300) as f64 / 100.0,
                failure_prob: rng.urange(0, 100) as f64 / 100.0,
            }),
            backend_errors: rng.flip().then(|| ErrorBurst {
                start: rng.urange(0, 16),
                length: rng.urange(0, 16),
            }),
        };
        f.validate().unwrap();
        let text = f.to_json().to_pretty();
        let back = FaultPlan::from_json(&json::parse(&text).unwrap()).unwrap();
        assert_eq!(f, back, "round trip changed the fault plan");
        assert_eq!(text, back.to_json().to_pretty(), "serialization not stable");
    });
}

#[test]
fn neutral_faults_reproduce_the_healthy_simulation() {
    // The regression pin behind every other fault property: injecting
    // nothing changes nothing, for both resident (spatial) and
    // time-multiplexed regimes — and the "healthy" baseline inside the
    // fault report is exactly what the plain plan simulation reports.
    let sim = Simulator { frames: 2 };
    for plan in [spatial_plan(), temporal_plan()] {
        let faulted = sim.simulate_faulted(&plan, &FaultPlan::none()).unwrap();
        let healthy = sim.simulate(&plan).unwrap();
        assert_eq!(faulted.tenants.len(), plan.tenants.len());
        for (t, ft) in faulted.tenants.iter().enumerate() {
            assert_eq!(
                ft.healthy_fps.to_bits(),
                healthy.tenants[t].fps.to_bits(),
                "tenant {t}: baseline diverged from the plain simulation"
            );
            assert_eq!(
                ft.degraded_fps.to_bits(),
                ft.healthy_fps.to_bits(),
                "tenant {t}: a neutral fault degraded the fabric"
            );
            assert_eq!(ft.fps.to_bits(), ft.degraded_fps.to_bits());
            assert_eq!(ft.served_frac.to_bits(), 1.0f64.to_bits());
        }
    }
}

#[test]
fn same_seed_fault_reports_are_byte_identical() {
    // The in-process half of the CI determinism gate: the same plan and
    // the same seeded fault scenario serialize to the same bytes, run
    // after run — including the stochastic reconfiguration-failure coins.
    let plan = temporal_plan();
    let sim = Simulator { frames: 1 };
    let a = sim.simulate_faulted(&plan, &full_fault()).unwrap();
    let b = sim.simulate_faulted(&plan, &full_fault()).unwrap();
    assert_eq!(a.to_json().to_pretty(), b.to_json().to_pretty());
    assert_eq!(a.seed, 42);
}

#[test]
fn ddr_brownout_strictly_reduces_throughput() {
    // A port at 5% of its rated bandwidth starves the weight streams of
    // every pipeline: each tenant's degraded rate must fall strictly
    // below its healthy baseline (fabric resources untouched).
    let plan = spatial_plan();
    let faults = FaultPlan {
        ddr_factor: Some(0.05),
        ..FaultPlan::none()
    };
    let report = Simulator { frames: 2 }.simulate_faulted(&plan, &faults).unwrap();
    for (t, ft) in report.tenants.iter().enumerate() {
        assert!(
            ft.degraded_fps < ft.healthy_fps,
            "tenant {t}: a 20x port brownout must cut throughput \
             ({} vs {})",
            ft.degraded_fps,
            ft.healthy_fps
        );
        assert_eq!(ft.served_frac.to_bits(), 1.0f64.to_bits());
    }
}

#[test]
fn board_loss_truncates_effective_fps() {
    // Board loss is an outage in time, not a slowdown: the degraded rate
    // is untouched and the effective rate scales by the served fraction
    // of the horizon — 0 at t=0, the full rate past the horizon, and
    // exactly the ratio in between.
    let plan = spatial_plan();
    let sim = Simulator { frames: 2 };
    let loss_at = |at_s: f64| FaultPlan {
        board_loss: Some(BoardLoss {
            at_s,
            survive_frac: 0.5,
        }),
        ..FaultPlan::none()
    };
    let horizon = sim.simulate_faulted(&plan, &FaultPlan::none()).unwrap().horizon_s;
    assert!(horizon > 0.0);

    let at_zero = sim.simulate_faulted(&plan, &loss_at(0.0)).unwrap();
    for ft in &at_zero.tenants {
        assert_eq!(ft.served_frac.to_bits(), 0.0f64.to_bits());
        assert_eq!(ft.fps.to_bits(), 0.0f64.to_bits());
        assert!(ft.degraded_fps > 0.0, "the rate itself is not the casualty");
    }

    let beyond = sim.simulate_faulted(&plan, &loss_at(horizon * 10.0)).unwrap();
    for ft in &beyond.tenants {
        assert_eq!(ft.served_frac.to_bits(), 1.0f64.to_bits());
        assert_eq!(ft.fps.to_bits(), ft.degraded_fps.to_bits());
    }

    let half = sim.simulate_faulted(&plan, &loss_at(horizon * 0.5)).unwrap();
    for (t, ft) in half.tenants.iter().enumerate() {
        assert!(
            (ft.served_frac - 0.5).abs() < 1e-12,
            "tenant {t}: served_frac {} for a mid-horizon loss",
            ft.served_frac
        );
        assert_eq!(
            ft.fps.to_bits(),
            (ft.degraded_fps * ft.served_frac).to_bits(),
            "tenant {t}: effective fps must be the truncation identity"
        );
    }
}

#[test]
fn reconfig_overrun_stretches_the_period_and_cuts_fps() {
    // A 50x configuration-port overrun turns the swap cost into the
    // period's dominant term: the executed horizon grows and every
    // tenant's effective rate drops — but no frame is ever dropped (the
    // DES stretches the period instead).
    let plan = temporal_plan();
    let sim = Simulator { frames: 1 };
    let healthy = sim.simulate_faulted(&plan, &FaultPlan::none()).unwrap();
    let faults = FaultPlan {
        reconfig: Some(ReconfigFault {
            overrun_factor: 50.0,
            failure_prob: 0.0,
        }),
        ..FaultPlan::none()
    };
    let slow = sim.simulate_faulted(&plan, &faults).unwrap();
    assert!(
        slow.horizon_s > healthy.horizon_s,
        "a 50x swap overrun must stretch the executed period \
         ({} vs {})",
        slow.horizon_s,
        healthy.horizon_s
    );
    for (t, (h, s)) in healthy.tenants.iter().zip(&slow.tenants).enumerate() {
        assert!(
            s.degraded_fps < h.degraded_fps,
            "tenant {t}: overrun must cut the effective rate"
        );
    }
}

#[test]
fn reconfig_failures_only_add_cost() {
    // Certain failure (every swap streamed twice) can never beat the
    // overrun-only schedule: per-tenant rates are at most the
    // failure-free ones and the executed horizon is at least as long.
    let plan = temporal_plan();
    let sim = Simulator { frames: 1 };
    let fault = |prob: f64| FaultPlan {
        seed: 7,
        reconfig: Some(ReconfigFault {
            overrun_factor: 2.0,
            failure_prob: prob,
        }),
        ..FaultPlan::none()
    };
    let clean = sim.simulate_faulted(&plan, &fault(0.0)).unwrap();
    let failing = sim.simulate_faulted(&plan, &fault(1.0)).unwrap();
    assert!(failing.horizon_s >= clean.horizon_s);
    for (t, (c, f)) in clean.tenants.iter().zip(&failing.tenants).enumerate() {
        assert!(
            f.degraded_fps <= c.degraded_fps,
            "tenant {t}: retried swaps cannot raise throughput"
        );
    }
}
