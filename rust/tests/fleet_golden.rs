//! Fleet-scale golden pins on the checked-in two-board example
//! (`examples/fleets/zc706_pair.json`: a full zc706 plus a half-capacity
//! sibling at 0.6× cost): vgg16 at W16A16 physically cannot fit the half
//! board (its weight working set overflows the halved BRAM), so every
//! frontier placement must route it — alone, weight exactly 1.0 — to the
//! full board; the whole planning document is byte-deterministic across
//! runs (the CI gate re-runs the CLI and diffs); the frontier survives
//! the crate's own reference reduction; and board loss resolves every
//! displaced tenant explicitly — migrated to a named peer, or shed with
//! the per-board reasons — never silently.

use flexipipe::board::zedboard;
use flexipipe::fault::{BoardLoss, FaultPlan};
use flexipipe::fleet::{frontier, FleetPlanner, FleetSpec};
use flexipipe::model::zoo;
use flexipipe::plan::{Planner, ReplanPhase, Workload};
use flexipipe::quant::QuantMode;
use flexipipe::sim::Simulator;

fn pair_spec() -> FleetSpec {
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../examples/fleets/zc706_pair.json");
    FleetSpec::load(path).unwrap()
}

fn pair_workload() -> Workload {
    Workload::new(QuantMode::W16A16)
        .tenant(zoo::vgg16())
        .tenant(zoo::alexnet())
        .tenant(zoo::zf())
}

fn board_loss(survive_frac: f64) -> FaultPlan {
    FaultPlan {
        board_loss: Some(BoardLoss {
            at_s: 0.25,
            survive_frac,
        }),
        ..FaultPlan::none()
    }
}

#[test]
fn zc706_pair_example_pins_placement_and_byte_determinism() {
    let spec = pair_spec();
    assert_eq!(spec.boards.len(), 2);
    assert_eq!(spec.boards[0].id, "zc706-a");
    assert_eq!(spec.boards[1].id, "zc706-half");
    assert_eq!(spec.boards[1].cost, 0.6);

    // The premise the placement pins rest on: vgg16 at W16A16 overflows
    // the half board's BRAM even alone.
    let solo = Planner::on(spec.boards[1].board.clone())
        .steps(4)
        .plan(&Workload::new(QuantMode::W16A16).tenant(zoo::vgg16()));
    assert!(solo.is_err(), "vgg16 must be solo-infeasible on zc706-half");

    let set = FleetPlanner::over(spec.clone()).steps(4).plan(&pair_workload()).unwrap();
    assert!(!set.plans.is_empty());
    for p in &set.plans {
        p.validate().unwrap();
        let vgg = p.routing.tenants.iter().find(|t| t.net == "vgg16").unwrap();
        assert_eq!(vgg.routes.len(), 1, "vgg16 cannot replicate onto the half board");
        assert_eq!(vgg.routes[0].board, "zc706-a");
        assert_eq!(vgg.routes[0].weight, 1.0);
    }
    // The planner's incremental frontier survives the reference reducer.
    assert_eq!(frontier(&set.plans).unwrap(), (0..set.plans.len()).collect::<Vec<_>>());
    // An exact solo-infeasible skip fires for every assignment putting
    // vgg16 on the half board — visible in the effort counters.
    assert!(set.stats.infeasible > 0, "solo-infeasible assignments must be skipped");

    // Byte-determinism, the property the CI cmp gate runs end to end:
    // plan → simulate → replan twice each, identical documents.
    let again = FleetPlanner::over(spec).steps(4).plan(&pair_workload()).unwrap();
    assert_eq!(set.to_json().to_pretty(), again.to_json().to_pretty());
    let sim = Simulator::default();
    let best = &set.plans[set.best];
    assert_eq!(
        sim.simulate_fleet(best).unwrap().to_json().to_pretty(),
        sim.simulate_fleet(&again.plans[again.best]).unwrap().to_json().to_pretty()
    );
}

#[test]
fn losing_the_full_board_accounts_for_every_displaced_tenant() {
    let spec = pair_spec();
    let planner = FleetPlanner::over(spec).steps(4);
    let set = planner.plan(&pair_workload()).unwrap_or_else(|e| panic!("{e}"));
    let incumbent = &set.plans[set.best];
    let faults = board_loss(0.875);

    let outcome = planner.replan(incumbent, &faults, "zc706-a").unwrap();
    let replay = planner.replan(incumbent, &faults, "zc706-a").unwrap();
    assert_eq!(
        outcome.to_json().to_pretty(),
        replay.to_json().to_pretty(),
        "fleet failover must be byte-deterministic (the CI cmp gate)"
    );
    assert_eq!(outcome.lost, "zc706-a");

    // Every tenant the lost board hosted is explicitly accounted for:
    // still served on its surviving capacity, migrated to a named peer,
    // a dropped replica, or shed with reasons — never silently gone.
    let lost_plan = &incumbent.boards.iter().find(|b| b.id == "zc706-a").unwrap().plan;
    for t in &lost_plan.tenants {
        let name = &t.net.name;
        let still_served = outcome.plan.as_ref().is_some_and(|p| {
            p.routing.tenants.iter().any(|tr| tr.net == *name)
        });
        let migrated = outcome.migrated.iter().any(|m| m.net == *name);
        let dropped = outcome.dropped_replicas.iter().any(|d| d.net == *name);
        let shed = outcome.shed.iter().any(|s| s.net == *name);
        assert!(
            still_served || migrated || dropped || shed,
            "tenant '{name}' vanished without an explicit outcome"
        );
    }
    // vgg16 is solo-infeasible on the only peer, so whatever happens it
    // never migrates there; if it could not be re-admitted on the
    // surviving capacity it must appear in the shed report with the
    // per-board reasons joined in.
    assert!(outcome.migrated.iter().all(|m| m.net != "vgg16"));
    for s in &outcome.shed {
        assert!(!s.reason.is_empty(), "shed entries must carry reasons");
    }
    if let Some(p) = &outcome.plan {
        p.validate().unwrap();
    }
}

#[test]
fn losing_a_twin_board_migrates_its_tenant_onto_the_peer() {
    // Two identical boards, one tenant each (the cost-2 frontier member
    // that maximizes both tenants' fps). Annihilate the board hosting
    // tinycnn: the fleet failover must migrate it onto the surviving
    // twin — peer re-planned with both tenants — shedding nothing.
    let spec = FleetSpec::new()
        .board("twin-a", zedboard(), 1.0)
        .board("twin-b", zedboard(), 1.0);
    let workload = Workload::new(QuantMode::W8A8)
        .tenant(zoo::tinycnn())
        .tenant(zoo::lenet());
    let planner = FleetPlanner::over(spec).steps(4);
    let set = planner.plan(&workload).unwrap();
    let split = set
        .plans
        .iter()
        .find(|p| p.boards.len() == 2 && p.boards.iter().all(|b| b.plan.tenants.len() == 1))
        .expect("the one-tenant-per-board split must be on the frontier");
    let lost = &split.boards.iter().find(|b| b.plan.tenants[0].net.name == "tinycnn").unwrap().id;
    let peer = &split.boards.iter().find(|b| b.id != *lost).unwrap().id;

    let outcome = planner.replan(split, &board_loss(0.01), lost).unwrap();
    assert_eq!(outcome.phase, ReplanPhase::FullSearch, "1% capacity defeats warm start");
    assert!(outcome.shed.is_empty(), "the peer must admit the displaced tenant");
    assert!(outcome.dropped_replicas.is_empty());
    assert_eq!(outcome.migrated.len(), 1);
    assert_eq!(outcome.migrated[0].net, "tinycnn");
    assert_eq!(&outcome.migrated[0].from, lost);
    assert_eq!(&outcome.migrated[0].to, peer);

    let degraded = outcome.plan.expect("the surviving twin still serves");
    degraded.validate().unwrap();
    assert_eq!(degraded.boards.len(), 1, "the lost board leaves the plan");
    assert_eq!(&degraded.boards[0].id, peer);
    assert_eq!(degraded.boards[0].plan.tenants.len(), 2);
    for tr in &degraded.routing.tenants {
        assert_eq!(tr.routes.len(), 1);
        assert_eq!(tr.routes[0].weight, 1.0);
    }
}
