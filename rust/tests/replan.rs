//! Failover re-planning: a DDR brownout warm-starts the incumbent plan
//! without shedding; a board loss re-admits every tenant when the
//! surviving capacity allows; binding fps floors shed the lowest-priority
//! tenant *explicitly* (never silently); an SLO the incumbent schedule
//! cannot meet forces a full re-plan whose executed sojourn the DES
//! confirms within 5% of the analytic bound (the PR-4 pin, re-asserted
//! post-failover); and an unachievable workload sheds every tenant with
//! reasons rather than returning a broken plan.

use flexipipe::alloc::Allocation;
use flexipipe::board::zc706;
use flexipipe::fault::{BoardLoss, FaultPlan};
use flexipipe::model::zoo;
use flexipipe::plan::{Constraint, DeploymentPlan, Planner, ReplanPhase, Workload};
use flexipipe::quant::QuantMode;
use flexipipe::shard::{Regime, ScheduleMode};
use flexipipe::sim;

fn fixture() -> DeploymentPlan {
    DeploymentPlan::load(concat!(
        env!("CARGO_MANIFEST_DIR"),
        "/../examples/plans/vgg16_alexnet_zc706.json"
    ))
    .unwrap()
}

#[test]
fn ddr_brownout_warm_starts_the_incumbent() {
    // A port brownout leaves the fabric whole: the incumbent's θ vectors
    // and schedule survive, the allocator re-derives each pipeline on the
    // degraded board, nothing is shed, and the outcome carries honest
    // re-measured records.
    let incumbent = fixture();
    let faults = FaultPlan {
        ddr_factor: Some(0.9),
        ..FaultPlan::none()
    };
    let outcome = Planner::on(zc706()).steps(16).replan(&incumbent, &faults).unwrap();
    assert_eq!(
        outcome.phase,
        ReplanPhase::WarmStart,
        "an intact fabric must keep the incumbent's quanta"
    );
    assert!(outcome.shed.is_empty(), "a brownout must not shed: {:?}", outcome.shed);
    let plan = outcome.plan.expect("brownout replan must produce a plan");
    assert_eq!(plan.tenants.len(), 2);
    assert!(
        (outcome.board.ddr_bytes_per_sec - 0.9 * incumbent.board.ddr_bytes_per_sec).abs()
            < 1.0,
        "the surviving board must carry the browned-out port"
    );
    assert_eq!(outcome.board.dsps, incumbent.board.dsps);
    for (t, pt) in plan.tenants.iter().enumerate() {
        let rec = pt.record.as_ref().expect("warm start must re-record figures");
        assert!(rec.fps > 0.0 && rec.fps.is_finite(), "tenant {t}: {}", rec.fps);
        assert!(!pt.stages.is_empty(), "tenant {t}: stages must be re-derived");
    }
    assert!(outcome.diff.is_some(), "the outcome must carry the transition");
}

#[test]
fn board_loss_readmits_both_tenants_when_capacity_allows() {
    // The degraded-admission acceptance case: losing 10% of the fabric
    // still leaves room for both tenants, so the replan re-admits both
    // and the shed report stays empty.
    let incumbent = fixture();
    let faults = FaultPlan {
        board_loss: Some(BoardLoss {
            at_s: 0.25,
            survive_frac: 0.9,
        }),
        ..FaultPlan::none()
    };
    let outcome = Planner::on(zc706()).steps(16).replan(&incumbent, &faults).unwrap();
    assert_eq!(
        outcome.board.dsps,
        (incumbent.board.dsps as f64 * 0.9).floor() as usize
    );
    assert!(outcome.shed.is_empty(), "capacity allows both: {:?}", outcome.shed);
    let plan = outcome.plan.expect("survivable loss must produce a plan");
    let names: Vec<&str> = plan.tenants.iter().map(|t| t.net.name.as_str()).collect();
    assert_eq!(names, ["vgg16", "alexnet"]);
    let diff = outcome.diff.unwrap();
    assert!(
        !diff.is_empty(),
        "moving to the surviving board is a real transition"
    );
}

#[test]
fn binding_floor_sheds_the_lowest_priority_tenant() {
    // The graceful-degradation acceptance case: half the board is gone
    // and vgg16 carries an fps floor only a (near-)solo deployment can
    // meet. The replan must shed alexnet — explicitly, with the planner's
    // reason — and the surviving vgg16 plan must meet its floor.
    //
    // The floor is derived at runtime so the test tracks the simulator:
    // strictly above the best vgg16 rate any two-tenant plan achieves on
    // the surviving board, strictly below the solo rate.
    let incumbent = fixture();
    let faults = FaultPlan {
        board_loss: Some(BoardLoss {
            at_s: 0.1,
            survive_frac: 0.5,
        }),
        ..FaultPlan::none()
    };
    let planner = Planner::on(zc706()).steps(4);
    let surviving = faults.surviving_board(&incumbent.board);
    let survivors = Planner {
        boards: vec![surviving.clone()],
        ..planner.clone()
    };
    let solo = survivors
        .plan(&Workload::new(QuantMode::W16A16).tenant(zoo::vgg16()))
        .unwrap();
    let solo_fps = solo.plans[solo.best].fps_vec().unwrap()[0];
    let joint = survivors
        .plan(
            &Workload::new(QuantMode::W16A16)
                .tenant(zoo::vgg16())
                .tenant(zoo::alexnet()),
        )
        .unwrap();
    let joint_max = joint
        .plans
        .iter()
        .map(|p| p.fps_vec().unwrap()[0])
        .fold(f64::NEG_INFINITY, f64::max);
    assert!(
        joint_max < solo_fps,
        "fixture premise: sharing must cost vgg16 throughput \
         ({joint_max} vs {solo_fps})"
    );
    let floor = 0.5 * (joint_max + solo_fps);

    let mut floored = incumbent.clone();
    floored.tenants[0].constraints = vec![Constraint::MinFps(floor)];
    let outcome = planner.replan(&floored, &faults).unwrap();

    assert_eq!(outcome.shed.len(), 1, "exactly one tenant gives way");
    assert_eq!(outcome.shed[0].net, "alexnet", "ties shed the later tenant");
    assert!(
        outcome.shed[0].reason.contains("infeasible on surviving capacity"),
        "shed report must carry the planner's reason: {}",
        outcome.shed[0].reason
    );
    let plan = outcome.plan.expect("vgg16 alone fits the surviving board");
    assert_eq!(plan.tenants.len(), 1);
    assert_eq!(plan.tenants[0].net.name, "vgg16");
    let fps = plan.fps_vec().unwrap()[0];
    assert!(
        fps >= floor,
        "the survivor's floor must hold post-failover ({fps} < {floor})"
    );
    let diff = outcome.diff.unwrap();
    assert_eq!(diff.removed.len(), 1, "the shed tenant leaves through the diff");
    assert_eq!(diff.removed[0].net, "alexnet");
}

#[test]
fn slo_forces_a_full_replan_and_des_confirms_sojourn_within_5pct() {
    // A transient outage with full recovered capacity, but the incumbent
    // is the worst-latency schedule and tenant 0 now carries an SLO only
    // a different schedule meets: the warm start must fail its measured
    // sojourn check, phase 2 must find an admissible schedule, and the
    // executed schedule's worst sojourn must confirm the analytic bound
    // within 5% (the PR-4 pin, re-asserted for the replanned plan).
    let planner = Planner::on(zc706())
        .steps(4)
        .schedule(ScheduleMode::Temporal)
        .max_period(0.1)
        .interleave(2);
    let workload = Workload::new(QuantMode::W8A8)
        .tenant(zoo::tinycnn())
        .tenant(zoo::lenet());
    let set = planner.plan(&workload).unwrap();
    let lat = |p: &DeploymentPlan| p.latency_vec().unwrap()[0];
    let lat_min = set.plans.iter().map(lat).fold(f64::INFINITY, f64::min);
    let incumbent = set
        .plans
        .iter()
        .max_by(|a, b| lat(a).total_cmp(&lat(b)))
        .unwrap()
        .clone();

    // The incumbent schedule's *measured* worst sojourn for tenant 0 —
    // what the warm start checks the SLO against.
    let allocs = incumbent.instantiate().unwrap();
    let refs: Vec<&Allocation> = allocs.iter().collect();
    let Regime::Temporal(info) = &incumbent.regime else {
        panic!("temporal-only search produced a spatial plan")
    };
    assert!(info.period_cycles > 0);
    let ts = sim::engines::simulate_schedule(&refs, &info.schedule_slices(), true);
    let warm_sojourn = ts.worst_sojourn[0] as f64 / incumbent.board.freq_hz;
    assert!(
        lat_min < warm_sojourn,
        "fixture premise: the schedule space must have latency spread \
         ({lat_min} vs {warm_sojourn})"
    );
    let slo = 0.5 * (lat_min + warm_sojourn);

    let mut constrained = incumbent.clone();
    constrained.tenants[0].constraints = vec![Constraint::Slo(slo)];
    let faults = FaultPlan {
        board_loss: Some(BoardLoss {
            at_s: 0.02,
            survive_frac: 1.0, // transient outage, full capacity recovered
        }),
        ..FaultPlan::none()
    };
    let outcome = planner.replan(&constrained, &faults).unwrap();
    assert_eq!(
        outcome.phase,
        ReplanPhase::FullSearch,
        "a temporal incumbent skips delta admission: its schedule re-derives \
         from scratch, so a failed warm start goes straight to the search"
    );
    assert!(outcome.shed.is_empty(), "the SLO is achievable: {:?}", outcome.shed);
    let plan = outcome.plan.expect("phase 2 must find an admissible schedule");
    assert!(
        plan.latency_vec().unwrap()[0] <= slo,
        "the replanned schedule must meet the SLO"
    );

    // Execute the replanned schedule: measured worst sojourn never
    // exceeds the analytic bound and agrees within 5%.
    let Regime::Temporal(info) = &plan.regime else {
        panic!("two-tenant temporal replan must stay temporal")
    };
    assert!(info.period_cycles > 0);
    let allocs = plan.instantiate().unwrap();
    let refs: Vec<&Allocation> = allocs.iter().collect();
    let ts = sim::engines::simulate_schedule(&refs, &info.schedule_slices(), true);
    for t in 0..plan.tenants.len() {
        let analytic = info.latency_cycles[t];
        let measured = ts.worst_sojourn[t];
        assert!(
            measured <= analytic,
            "tenant {t}: measured sojourn {measured} exceeds the analytic \
             bound {analytic}"
        );
        let rel = (analytic - measured) as f64 / analytic as f64;
        assert!(
            rel <= 0.05,
            "tenant {t}: measured sojourn {measured} vs analytic {analytic} \
             ({:.2}% apart)",
            rel * 100.0
        );
    }
}

#[test]
fn unachievable_floors_shed_every_tenant_explicitly() {
    // No silent drops, even when nothing fits: impossible floors on both
    // tenants shed both, in priority order (equal weights shed the later
    // tenant first), each with a reason — and the outcome says plainly
    // that there is no plan.
    let mut incumbent = fixture();
    for t in &mut incumbent.tenants {
        t.constraints = vec![Constraint::MinFps(1e9)];
    }
    let outcome = Planner::on(zc706())
        .steps(4)
        .replan(&incumbent, &FaultPlan::none())
        .unwrap();
    assert_eq!(outcome.phase, ReplanPhase::FullSearch);
    assert!(outcome.plan.is_none());
    assert!(outcome.diff.is_none());
    let shed: Vec<&str> = outcome.shed.iter().map(|s| s.net.as_str()).collect();
    assert_eq!(shed, ["alexnet", "vgg16"], "later tenants give way first");
    for s in &outcome.shed {
        assert!(
            s.reason.contains("infeasible on surviving capacity"),
            "{}",
            s.reason
        );
    }
}

#[test]
fn spatial_floor_delta_admits_a_quantum_neighbor() {
    // The delta-admission acceptance case: the incumbent's own quanta miss
    // a new fps floor, but a ±1-quantum neighbor meets it — Phase 1b must
    // take it (and say so), never falling through to the full search.
    //
    // Premises are derived at runtime with the same DES pass `replan`
    // itself checks candidates with (spatial provisioned shares, 2 frames,
    // β = Θ), so the floor is guaranteed to sit strictly between the
    // incumbent's measured rate and an in-neighborhood candidate's.
    let planner = Planner::on(zc706()).steps(4);
    let workload = Workload::new(QuantMode::W16A16)
        .tenant(zoo::vgg16())
        .tenant(zoo::alexnet());
    let set = planner.plan(&workload).unwrap();
    let measured = |p: &DeploymentPlan| -> f64 {
        let allocs = p.instantiate().unwrap();
        let refs: Vec<&Allocation> = allocs.iter().collect();
        let shares: Vec<f64> = p.tenants.iter().map(|t| t.ddr_share).collect();
        sim::engines::simulate_multi_provisioned(&refs, &shares, &p.board, 2)[0].fps
    };
    let quanta_neighbors = |a: &DeploymentPlan, b: &DeploymentPlan| -> bool {
        let mut moved = 0usize;
        for (x, y) in a.tenants.iter().zip(&b.tenants) {
            let dd = x.dsp_parts.abs_diff(y.dsp_parts);
            let bd = x.bram_parts.abs_diff(y.bram_parts);
            if dd > 1 || bd > 1 {
                return false;
            }
            moved += dd + bd;
        }
        moved > 0
    };
    let mut pair = None;
    'outer: for p in &set.plans {
        for q in &set.plans {
            if quanta_neighbors(p, q) {
                let (fp, fq) = (measured(p), measured(q));
                if fq > fp * 1.05 {
                    pair = Some((p.clone(), fp, fq));
                    break 'outer;
                }
            }
        }
    }
    let (incumbent, fp, fq) = pair.expect(
        "fixture premise: the 1/4-quanta spatial lattice must contain a ±1 \
         neighbor pair with measured fps spread for tenant 0",
    );
    let floor = 0.5 * (fp + fq);

    let mut floored = incumbent;
    floored.tenants[0].constraints = vec![Constraint::MinFps(floor)];
    let outcome = planner.replan(&floored, &FaultPlan::none()).unwrap();
    assert_eq!(
        outcome.phase,
        ReplanPhase::DeltaAdmission,
        "a quantum shift absorbs the floor: the full search must not run"
    );
    assert!(outcome.shed.is_empty(), "delta admission sheds nothing: {:?}", outcome.shed);
    let plan = outcome.plan.expect("the admitted neighbor is the new plan");
    let rec = plan.tenants[0].record.as_ref().expect("admission re-records figures");
    assert!(
        rec.fps >= floor,
        "the admitted neighbor must meet the floor ({} < {floor})",
        rec.fps
    );
    assert!(
        outcome
            .to_json()
            .to_pretty()
            .contains("\"phase\": \"delta-admission\""),
        "the outcome JSON must name the deciding phase"
    );
}

#[test]
fn overlay_incumbent_falls_back_to_full_search() {
    // The third regime: an overlay incumbent's quanta neighborhood is
    // meaningless (the superset datapath re-derives admission whole), so a
    // failed warm start must go straight to the full search — explicitly,
    // via the outcome's phase — and impossible floors still shed every
    // tenant with reasons.
    let planner = Planner::on(zc706()).steps(4).schedule(ScheduleMode::Overlay);
    let workload = Workload::new(QuantMode::W8A8)
        .tenant(zoo::tinycnn())
        .tenant(zoo::lenet());
    let set = planner.plan(&workload).unwrap();
    let mut incumbent = set.plans[set.best].clone();
    assert!(
        matches!(incumbent.regime, Regime::Temporal(_)),
        "overlay plans carry the schedule regime"
    );
    for t in &mut incumbent.tenants {
        t.constraints = vec![Constraint::MinFps(1e18)];
    }
    let outcome = planner.replan(&incumbent, &FaultPlan::none()).unwrap();
    assert_eq!(
        outcome.phase,
        ReplanPhase::FullSearch,
        "non-spatial incumbents skip delta admission — and the skip is \
         recorded, not silent"
    );
    assert!(outcome.plan.is_none());
    assert_eq!(outcome.shed.len(), 2, "both impossible floors shed: {:?}", outcome.shed);
}
