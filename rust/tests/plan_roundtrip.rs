//! The plan-centric acceptance suite: a [`DeploymentPlan`] produced by the
//! planner round-trips through JSON and re-simulates **bit-identically**
//! to the in-process search; unknown format versions are rejected; the
//! checked-in example plan guards the on-disk format against drift; and
//! fps floors prune SLO-optimal plans that starve a throughput tenant.

use flexipipe::board::{zc706, zedboard};
use flexipipe::model::zoo;
use flexipipe::plan::{DeploymentPlan, Planner, TenantSpec, Workload, PLAN_VERSION};
use flexipipe::quant::QuantMode;
use flexipipe::shard::ScheduleMode;
use flexipipe::sim::{Simulate, Simulator};
use flexipipe::util::json;

fn two_tenant_workload() -> Workload {
    Workload::new(QuantMode::W8A8)
        .tenant(zoo::tinycnn())
        .tenant(zoo::lenet())
}

#[test]
fn spatial_plan_file_resimulates_bit_identically() {
    // Acceptance: plan → JSON file → load → Simulate reproduces the
    // in-process search's DES validation bit-for-bit, for every
    // validated frontier plan.
    let set = Planner::on(zedboard())
        .steps(8)
        .validate(2)
        .plan(&two_tenant_workload())
        .unwrap();
    let dir = std::env::temp_dir().join("flexipipe_plan_roundtrip");
    std::fs::create_dir_all(&dir).unwrap();
    for &i in &set.frontier {
        let plan = &set.plans[i];
        let path = dir.join(format!("spatial_{i}.json"));
        plan.save(&path).unwrap();
        let loaded = DeploymentPlan::load(&path).unwrap();
        // Byte-stable serialization.
        assert_eq!(
            plan.to_json().to_pretty(),
            loaded.to_json().to_pretty(),
            "plan {i} serialization not stable"
        );
        let sim = Simulator { frames: 2 };
        let fresh = sim.simulate(plan).unwrap();
        let reloaded = sim.simulate(&loaded).unwrap();
        for (t, (a, b)) in fresh.tenants.iter().zip(&reloaded.tenants).enumerate() {
            assert_eq!(a.fps.to_bits(), b.fps.to_bits(), "plan {i} tenant {t}");
            assert_eq!(a.makespan, b.makespan, "plan {i} tenant {t}");
            let recorded = plan.tenants[t]
                .record
                .as_ref()
                .and_then(|r| r.sim_fps)
                .expect("validated frontier plans record sim fps");
            assert_eq!(
                b.fps.to_bits(),
                recorded.to_bits(),
                "plan {i} tenant {t}: file-loaded plan diverged from the search DES"
            );
        }
    }
}

#[test]
fn temporal_plan_file_resimulates_bit_identically() {
    // Same acceptance for a time-multiplexed plan: one executed schedule
    // period, reconfiguration and all, identical through the file.
    let set = Planner::on(zc706())
        .steps(4)
        .schedule(ScheduleMode::Temporal)
        .max_period(0.1)
        .validate(1)
        .plan(&two_tenant_workload())
        .unwrap();
    let idx = set.frontier[0];
    let plan = &set.plans[idx];
    assert_eq!(plan.regime.label(), "temporal");
    let dir = std::env::temp_dir().join("flexipipe_plan_roundtrip");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("temporal.json");
    plan.save(&path).unwrap();
    let loaded = DeploymentPlan::load(&path).unwrap();
    assert_eq!(plan.to_json().to_pretty(), loaded.to_json().to_pretty());
    let sim = Simulator { frames: 1 };
    let fresh = sim.simulate(plan).unwrap();
    let reloaded = sim.simulate(&loaded).unwrap();
    for (t, (a, b)) in fresh.tenants.iter().zip(&reloaded.tenants).enumerate() {
        assert_eq!(a.fps.to_bits(), b.fps.to_bits(), "tenant {t}");
        let recorded = plan.tenants[t]
            .record
            .as_ref()
            .and_then(|r| r.sim_fps)
            .expect("validated frontier plans record sim fps");
        assert_eq!(b.fps.to_bits(), recorded.to_bits(), "tenant {t}");
    }
}

#[test]
fn unknown_version_plan_files_are_rejected() {
    let set = Planner::on(zedboard())
        .steps(4)
        .plan(&Workload::new(QuantMode::W8A8).tenant(zoo::lenet()))
        .unwrap();
    let text = set.plans[set.best].to_json().to_pretty();
    // A future format version must be refused, not half-read.
    let bumped = text.replacen(
        &format!("\"version\": {PLAN_VERSION}"),
        "\"version\": 99",
        1,
    );
    assert_ne!(text, bumped, "fixture must actually bump the version");
    let err = DeploymentPlan::from_json(&json::parse(&bumped).unwrap()).unwrap_err();
    assert!(err.to_string().contains("version 99"), "{err}");
}

#[test]
fn version_rejection_names_the_path_and_supported_range() {
    // The actionable half of the version gate: loading a future-versioned
    // *file* must say which file, which version it found, which range
    // this build reads, and how to fix it — not just "unsupported".
    let set = Planner::on(zedboard())
        .steps(4)
        .plan(&Workload::new(QuantMode::W8A8).tenant(zoo::lenet()))
        .unwrap();
    let text = set.plans[set.best].to_json().to_pretty();
    let bumped = text.replacen(
        &format!("\"version\": {PLAN_VERSION}"),
        "\"version\": 99",
        1,
    );
    assert_ne!(text, bumped);
    let dir = std::env::temp_dir().join("flexipipe_plan_roundtrip");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("future_version.json");
    std::fs::write(&path, &bumped).unwrap();
    let err = DeploymentPlan::load(&path).unwrap_err().to_string();
    assert!(err.contains("version 99"), "{err}");
    assert!(err.contains("1..=1"), "{err}");
    assert!(err.contains("regenerate"), "{err}");
    assert!(
        err.contains(path.display().to_string().as_str()),
        "the error must name the offending file: {err}"
    );
}

#[test]
fn checked_in_example_plan_parses_and_resimulates() {
    // The format-drift guard: the repository ships a plan file
    // (examples/plans/vgg16_alexnet_zc706.json, re-simulated by CI);
    // this build must parse it, round-trip it stably, rehydrate its
    // allocations, and execute it.
    let path = concat!(
        env!("CARGO_MANIFEST_DIR"),
        "/../examples/plans/vgg16_alexnet_zc706.json"
    );
    let plan = DeploymentPlan::load(path).unwrap();
    assert_eq!(plan.version, PLAN_VERSION);
    assert_eq!(plan.board.name, "zc706");
    assert_eq!(plan.tenants.len(), 2);
    assert_eq!(plan.tenants[0].net.name, "vgg16");
    assert_eq!(plan.tenants[1].net.name, "alexnet");
    assert_eq!(plan.regime.label(), "temporal");
    // Semantic round-trip stability (the hand-authored file may order
    // fields differently, but value → text → value is a fixed point).
    let text = plan.to_json().to_pretty();
    let back = DeploymentPlan::from_json(&json::parse(&text).unwrap()).unwrap();
    assert_eq!(text, back.to_json().to_pretty());
    // The plan executes: full-board vgg16 + alexnet @16b on the zc706.
    let allocs = plan.instantiate().unwrap();
    assert_eq!(allocs.len(), 2);
    let report = Simulator { frames: 1 }.simulate(&plan).unwrap();
    assert_eq!(report.tenants.len(), 2);
    assert!(
        report.tenant_fps().iter().all(|&f| f > 0.0 && f.is_finite()),
        "checked-in plan must serve both tenants: {:?}",
        report.tenant_fps()
    );
}

#[test]
fn min_fps_floor_prunes_the_slo_only_pick() {
    // Two lenet tenants, temporal with interleaving allowed: the
    // latency-optimal plan for tenant 0 (what an SLO-only planner picks)
    // interleaves its quanta and pays throughput for it. An fps floor on
    // tenant 0 strictly between that plan's rate and the best rate must
    // prune the SLO-only pick while keeping the workload feasible.
    let planner = Planner {
        calib_frames: 8,
        ..Planner::on(zc706())
            .steps(4)
            .schedule(ScheduleMode::Temporal)
            .interleave(2)
            .max_period(0.1)
    };
    let free = planner
        .plan(
            &Workload::new(QuantMode::W8A8)
                .tenant(zoo::lenet())
                .tenant(zoo::lenet()),
        )
        .unwrap();
    let obj: Vec<(f64, f64)> = free
        .plans
        .iter()
        .map(|p| (p.fps_vec().unwrap()[0], p.latency_vec().unwrap()[0]))
        .collect();
    let (slo_pick_fps, slo_pick_lat) = obj
        .iter()
        .copied()
        .min_by(|a, b| a.1.total_cmp(&b.1))
        .unwrap();
    let best_fps = obj.iter().map(|&(f, _)| f).fold(f64::NEG_INFINITY, f64::max);
    let worst_lat = obj.iter().map(|&(_, l)| l).fold(f64::NEG_INFINITY, f64::max);
    assert!(
        slo_pick_fps < best_fps,
        "fixture: the latency-optimal plan must pay throughput \
         ({slo_pick_fps} vs {best_fps})"
    );
    let floor = 0.5 * (slo_pick_fps + best_fps);

    // Re-plan with a loose SLO (admits every plan) plus the floor on
    // tenant 0: the SLO-only pick violates the floor and is pruned.
    let constrained = planner
        .plan(
            &Workload::new(QuantMode::W8A8)
                .tenant_spec(
                    TenantSpec::new(zoo::lenet())
                        .slo(worst_lat * 1.01)
                        .min_fps(floor),
                )
                .tenant(zoo::lenet()),
        )
        .unwrap();
    assert!(
        constrained.plans.len() < free.plans.len(),
        "the floor must prune at least the SLO-only pick"
    );
    for p in &constrained.plans {
        assert!(
            p.fps_vec().unwrap()[0] >= floor,
            "a surviving plan starves the floored tenant"
        );
        assert!(p.latency_vec().unwrap()[0] <= worst_lat * 1.01);
    }
    // The pruned set no longer contains the SLO-only pick's objective
    // point (its fps was below the floor by construction).
    assert!(slo_pick_fps < floor);
    let still_there = constrained.plans.iter().any(|p| {
        p.fps_vec().unwrap()[0].to_bits() == slo_pick_fps.to_bits()
            && p.latency_vec().unwrap()[0].to_bits() == slo_pick_lat.to_bits()
    });
    assert!(!still_there, "the SLO-only pick survived its floor");
}
