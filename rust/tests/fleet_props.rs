//! Fleet-planning property pins: a single-board fleet reproduces the
//! single-board `Planner` bit for bit; replication across two identical
//! boards doubles a tenant's fps exactly (planned *and* DES-measured —
//! `x + x == 2x` is exact in IEEE), splitting the routing weights exactly
//! in half; routing tables conserve traffic (weights sum to 1, every
//! route lands on a hosting board); the fleet frontier equals an
//! independent exhaustive reference reduction built directly on the
//! single-board `Planner`; branch-and-bound assignment pruning changes
//! effort counters but not one byte of the result; and an fps floor
//! above any single board's reach is met through replication — the
//! per-board solve drops the floor, the fleet-level sum enforces it.

use flexipipe::board::zedboard;
use flexipipe::fleet::{frontier, FleetPlan, FleetPlanner, FleetSpec};
use flexipipe::model::zoo;
use flexipipe::plan::{DeploymentPlan, Planner, TenantSpec, Workload};
use flexipipe::quant::QuantMode;
use flexipipe::sim::{Simulate, Simulator};
use flexipipe::util::json;

fn one_board() -> FleetSpec {
    FleetSpec::new().board("solo", zedboard(), 1.0)
}

fn twin_boards() -> FleetSpec {
    FleetSpec::new()
        .board("twin-a", zedboard(), 1.0)
        .board("twin-b", zedboard(), 1.0)
}

#[test]
fn single_board_fleet_reproduces_the_planner_bitwise() {
    // The degenerate fleet is the exactness anchor: one board, no
    // replication, no spill — the fleet frontier must be the Planner's
    // frontier, each embedded per-board plan byte-identical, each tenant
    // routed to the one board with weight exactly 1.0.
    let workload = Workload::new(QuantMode::W8A8)
        .tenant(zoo::tinycnn())
        .tenant(zoo::lenet());
    let fset = FleetPlanner::over(one_board()).steps(4).plan(&workload).unwrap();
    let pset = Planner::on(zedboard()).steps(4).plan(&workload).unwrap();

    assert_eq!(fset.plans.len(), pset.frontier.len(), "one plan per Planner frontier member");
    for (fp, &pi) in fset.plans.iter().zip(&pset.frontier) {
        assert_eq!(fp.boards.len(), 1);
        assert_eq!(fp.boards[0].id, "solo");
        assert_eq!(
            fp.boards[0].plan.to_json().to_pretty(),
            pset.plans[pi].to_json().to_pretty(),
            "the embedded per-board plan must be the Planner's, bit for bit"
        );
        for tr in &fp.routing.tenants {
            assert_eq!(tr.routes.len(), 1);
            assert_eq!(tr.routes[0].weight, 1.0, "solo routing is exact, not ≈1");
        }
        fp.validate().unwrap();
    }
    // Scalar objective picks agree in value. (The fleet set indexes its
    // frontier-only listing, the PlanSet all feasible plans — indices
    // differ; a tie-broken off-frontier pick is weakly dominated by a
    // frontier member, so the objective *values* still coincide bitwise.)
    assert_eq!(
        fset.plans[fset.best_min].min_fps().unwrap(),
        pset.plans[pset.best_min].min_fps().unwrap()
    );
    assert_eq!(
        fset.plans[fset.best_weighted].weighted_fps().unwrap(),
        pset.plans[pset.best_weighted].weighted_fps().unwrap()
    );
}

#[test]
fn replication_on_twin_boards_doubles_fps_bit_exactly() {
    // Two identical boards, one tenant: the frontier must contain the
    // replicated placement (it strictly improves fps over either solo
    // placement, at strictly higher cost — non-dominated on the cost
    // axis), and the combo pairing the *same* sub-plan on both twins has
    // fleet fps exactly 2x the sub-plan's and weights exactly 0.5 each.
    let workload = Workload::new(QuantMode::W8A8).tenant(zoo::lenet());
    let fset = FleetPlanner::over(twin_boards()).steps(4).plan(&workload).unwrap();

    let rep: Vec<&FleetPlan> = fset.plans.iter().filter(|p| p.boards.len() == 2).collect();
    assert!(!rep.is_empty(), "the replicated placement must be on the frontier");
    let twin = rep
        .iter()
        .find(|p| {
            p.boards[0].plan.to_json().to_pretty() == p.boards[1].plan.to_json().to_pretty()
        })
        .expect("identical boards expose the identical-sub-plan pairing");

    let sub_fps = twin.boards[0].plan.fps_vec().unwrap()[0];
    assert_eq!(
        twin.fps_vec().unwrap()[0],
        2.0 * sub_fps,
        "planned fleet fps must be the exact IEEE sum of the replicas"
    );
    for r in &twin.routing.tenants[0].routes {
        assert_eq!(r.weight, 0.5, "identical replicas split traffic exactly in half");
    }

    // DES-validated additivity: simulate_fleet runs each twin's pinned
    // engine (bit-identical runs of the same plan) and sums.
    let sim = Simulator::default();
    let fleet_report = sim.simulate_fleet(twin).unwrap();
    let solo_report = sim.simulate(&twin.boards[0].plan).unwrap();
    assert_eq!(
        fleet_report.tenants[0].fps,
        2.0 * solo_report.tenants[0].fps,
        "measured fleet fps must be the exact sum of two identical DES runs"
    );
    for r in &fleet_report.tenants[0].routes {
        assert_eq!(r.fps, solo_report.tenants[0].fps);
        assert_eq!(r.weight, 0.5);
    }
}

#[test]
fn routing_tables_conserve_traffic_on_every_frontier_plan() {
    // Conservation across a real multi-tenant, multi-board search: every
    // frontier plan validates (weights in (0,1], per-tenant sum within
    // 1e-9 of 1, every route lands on a board whose plan hosts the
    // tenant, every hosted tenant routed), and each weight is exactly the
    // hosting record's fps share — the same division `plan()` routed with.
    let workload = Workload::new(QuantMode::W8A8)
        .tenant(zoo::tinycnn())
        .tenant(zoo::lenet());
    let fset = FleetPlanner::over(twin_boards()).steps(4).plan(&workload).unwrap();
    assert!(!fset.plans.is_empty());
    for p in &fset.plans {
        p.validate().unwrap();
        for tr in &p.routing.tenants {
            let total: f64 = tr
                .routes
                .iter()
                .map(|r| {
                    let pl = p.boards.iter().find(|b| b.id == r.board).unwrap();
                    let t = pl.plan.tenants.iter().find(|t| t.net.name == tr.net).unwrap();
                    t.record.as_ref().unwrap().fps
                })
                .sum();
            for r in &tr.routes {
                let pl = p.boards.iter().find(|b| b.id == r.board).unwrap();
                let t = pl.plan.tenants.iter().find(|t| t.net.name == tr.net).unwrap();
                assert_eq!(
                    r.weight,
                    t.record.as_ref().unwrap().fps / total,
                    "weight must be the exact fps fraction ({}@{})",
                    tr.net,
                    r.board
                );
            }
        }
    }
}

/// Strict vector dominance, re-stated independently of the crate
/// internals: a ≥ b on every fps axis, ≤ on every cost/latency axis, and
/// strictly better somewhere.
fn dominates(au: &[f64], ad: &[f64], bu: &[f64], bd: &[f64]) -> bool {
    let ge = au.iter().zip(bu).all(|(a, b)| a >= b) && ad.iter().zip(bd).all(|(a, b)| a <= b);
    let strict = au.iter().zip(bu).any(|(a, b)| a > b) || ad.iter().zip(bd).any(|(a, b)| a < b);
    ge && strict
}

#[test]
fn fleet_frontier_matches_an_exhaustive_reference_reduction() {
    // Completeness and soundness against an independent oracle: enumerate
    // every tenant→board-subset assignment by hand, solve each board's
    // sub-workload with the single-board `Planner` directly, combine
    // sub-plan frontiers with the documented arithmetic (fps sums,
    // latency maxes, cost sums), reference-reduce, and demand the
    // planner's frontier matches as a multiset of objective vectors —
    // bit for bit.
    let costs = [1.0, 1.0];
    let workload = Workload::new(QuantMode::W8A8)
        .tenant(zoo::tinycnn())
        .tenant(zoo::lenet());
    let fset = FleetPlanner::over(twin_boards()).steps(4).plan(&workload).unwrap();

    // Oracle. Subsets of 2 boards: {a}=0b01, {b}=0b10, {a,b}=0b11.
    let solve = |tenant_idx: &[usize]| -> Option<Vec<(Vec<f64>, Vec<f64>)>> {
        let mut w = Workload::new(QuantMode::W8A8);
        for &t in tenant_idx {
            w = w.tenant_spec(TenantSpec::new(match t {
                0 => zoo::tinycnn(),
                _ => zoo::lenet(),
            }));
        }
        let set = Planner::on(zedboard()).steps(4).plan(&w).ok()?;
        Some(
            set.frontier
                .iter()
                .map(|&i| {
                    let p: &DeploymentPlan = &set.plans[i];
                    (p.fps_vec().unwrap(), p.latency_vec().unwrap())
                })
                .collect(),
        )
    };
    let mut candidates: Vec<(Vec<f64>, Vec<f64>)> = Vec::new();
    for m0 in [0b01u32, 0b10, 0b11] {
        for m1 in [0b01u32, 0b10, 0b11] {
            let masks = [m0, m1];
            let used: Vec<usize> =
                (0..2).filter(|&b| masks.iter().any(|m| m & (1 << b) != 0)).collect();
            let cost: f64 = used.iter().map(|&b| costs[b]).sum();
            let per_board: Option<Vec<(Vec<usize>, Vec<(Vec<f64>, Vec<f64>)>)>> = used
                .iter()
                .map(|&b| {
                    let idx: Vec<usize> = (0..2).filter(|&t| masks[t] & (1 << b) != 0).collect();
                    solve(&idx).map(|plans| (idx, plans))
                })
                .collect();
            let Some(per_board) = per_board else { continue };
            // Cross product, first used board outermost.
            let sizes: Vec<usize> = per_board.iter().map(|(_, p)| p.len()).collect();
            let combos: usize = sizes.iter().product();
            for c in 0..combos {
                let mut rem = c;
                let mut choice = vec![0usize; sizes.len()];
                for i in (0..sizes.len()).rev() {
                    choice[i] = rem % sizes[i];
                    rem /= sizes[i];
                }
                let mut fps = vec![0.0f64; 2];
                let mut lat = vec![0.0f64; 2];
                for (i, (idx, plans)) in per_board.iter().enumerate() {
                    let (pf, pl) = &plans[choice[i]];
                    for (pos, &t) in idx.iter().enumerate() {
                        fps[t] += pf[pos];
                        lat[t] = lat[t].max(pl[pos]);
                    }
                }
                let mut downs = vec![cost];
                downs.extend_from_slice(&lat);
                candidates.push((fps, downs));
            }
        }
    }
    let mut reference: Vec<(Vec<f64>, Vec<f64>)> = Vec::new();
    for (i, (u, d)) in candidates.iter().enumerate() {
        let beaten = candidates
            .iter()
            .enumerate()
            .any(|(j, (ju, jd))| j != i && dominates(ju, jd, u, d));
        let duplicate = candidates[..i].contains(&(u.clone(), d.clone()));
        if !beaten && !duplicate {
            reference.push((u.clone(), d.clone()));
        }
    }

    let mut got: Vec<String> = fset
        .plans
        .iter()
        .map(|p| format!("{:?}", p.objectives().unwrap()))
        .collect();
    let mut want: Vec<String> = reference.iter().map(|o| format!("{o:?}")).collect();
    got.sort();
    want.sort();
    assert_eq!(got, want, "fleet frontier must equal the exhaustive reference reduction");

    // And the crate's own reference reducer agrees the result is tight.
    assert_eq!(frontier(&fset.plans).unwrap(), (0..fset.plans.len()).collect::<Vec<_>>());
}

#[test]
fn pruned_fleet_search_is_bitwise_equal_to_exhaustive() {
    // Branch-and-bound is an optimization, never an approximation: the
    // whole result document — every plan, every route, every pick — must
    // be byte-identical with and without pruning; only the effort
    // counters move.
    let workload = Workload::new(QuantMode::W8A8)
        .tenant(zoo::tinycnn())
        .tenant(zoo::lenet());
    let exhaustive = FleetPlanner::over(twin_boards()).steps(4).plan(&workload).unwrap();
    let pruned = FleetPlanner::over(twin_boards())
        .steps(4)
        .prune(true)
        .plan(&workload)
        .unwrap();
    let strip = |s: &flexipipe::fleet::FleetPlanSet| -> Vec<String> {
        s.plans.iter().map(|p| p.to_json().to_pretty()).collect()
    };
    assert_eq!(strip(&exhaustive), strip(&pruned));
    assert_eq!(exhaustive.best_min, pruned.best_min);
    assert_eq!(exhaustive.best_weighted, pruned.best_weighted);
    assert_eq!(exhaustive.best, pruned.best);
    assert_eq!(exhaustive.stats.assignments, pruned.stats.assignments);
    assert_eq!(
        pruned.stats.bound_skipped + pruned.stats.solved + pruned.stats.infeasible,
        pruned.stats.assignments,
        "every assignment is accounted for: solved, infeasible, or bound-skipped"
    );
    assert_eq!(exhaustive.stats.bound_skipped, 0, "exhaustive mode never bound-skips");
}

#[test]
fn floor_above_single_board_reach_is_met_through_replication() {
    // Constraint semantics under replication, end to end: a MinFps floor
    // 1.5x the best any single board achieves is infeasible per board —
    // the sub-workload drops the floor for replicated tenants and the
    // fleet-level sum enforces it — so every returned placement must
    // replicate, and every returned placement must meet the floor.
    let solo = Planner::on(zedboard())
        .steps(4)
        .plan(&Workload::new(QuantMode::W8A8).tenant(zoo::lenet()))
        .unwrap();
    let solo_max = solo
        .plans
        .iter()
        .filter_map(|p| p.fps_vec().map(|v| v[0]))
        .fold(f64::NEG_INFINITY, f64::max);
    let floor = 1.5 * solo_max;

    let workload = Workload::new(QuantMode::W8A8)
        .tenant_spec(TenantSpec::new(zoo::lenet()).min_fps(floor));
    let fset = FleetPlanner::over(twin_boards()).steps(4).plan(&workload).unwrap();
    assert!(!fset.plans.is_empty(), "replication must rescue the floor");
    for p in &fset.plans {
        assert_eq!(
            p.boards.len(),
            2,
            "no single board reaches the floor — every kept placement replicates"
        );
        let fps = p.fps_vec().unwrap()[0];
        assert!(fps >= floor, "fleet floor must hold ({fps} < {floor})");
    }

    // The same floor with replication capped at 1 board is an explicit
    // error, not a silent empty frontier.
    let err = FleetPlanner::over(twin_boards())
        .steps(4)
        .replicas(1)
        .plan(&workload)
        .unwrap_err()
        .to_string();
    assert!(err.contains("no feasible fleet placement"), "{err}");
}

#[test]
fn unknown_fleet_plan_versions_are_rejected_end_to_end() {
    // The versioned-format contract, fleet edition: a plan from the
    // future is refused at load with the found version and the supported
    // range — same idiom as plan/fault/trace formats.
    let workload = Workload::new(QuantMode::W8A8).tenant(zoo::lenet());
    let fset = FleetPlanner::over(one_board()).steps(4).plan(&workload).unwrap();
    // Bump the *fleet* version key, not the embedded per-board plan's —
    // both formats carry one, so edit the parsed document, not the text.
    let mut doc = fset.plans[fset.best].to_json();
    if let json::Value::Obj(m) = &mut doc {
        m.insert("version".to_string(), json::num(99));
    }
    let dir = std::env::temp_dir().join("flexipipe_fleet_props");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("future_fleet_plan.json");
    std::fs::write(&path, doc.to_pretty()).unwrap();
    let err = FleetPlan::load(&path).unwrap_err().to_string();
    assert!(err.contains("version 99"), "{err}");
    assert!(err.contains("1..=1"), "{err}");
    assert!(err.contains("future_fleet_plan.json"), "{err}");
    std::fs::remove_file(&path).ok();
}
