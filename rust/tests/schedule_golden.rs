//! Golden tests for the merged spatial + temporal shard frontier
//! (`--schedule auto`), seeded with the paper pair vgg16 + alexnet on the
//! ZC706 — the acceptance case of the time-multiplexed sharding issue.

use flexipipe::board::zc706;
use flexipipe::model::zoo;
use flexipipe::quant::QuantMode;
use flexipipe::shard::{plan_dominates, Regime, ScheduleMode, Sharder, Tenant};

fn auto_sharder() -> Sharder {
    Sharder {
        steps: 8,
        schedule: ScheduleMode::Auto,
        max_period_s: 1.0,
        sim_frames: 1,
        ..Sharder::new(
            zc706(),
            vec![
                Tenant::new(zoo::vgg16(), QuantMode::W16A16),
                Tenant::new(zoo::alexnet(), QuantMode::W16A16),
            ],
        )
    }
}

#[test]
fn merged_frontier_is_nondominated_and_complete_across_regimes() {
    let result = auto_sharder().search().unwrap();

    // Both regimes must be represented in the merged plan space: the
    // spatial split space (the PR-2 acceptance case) and full-board
    // time-multiplexed schedules.
    let n_spatial = result.plans.iter().filter(|p| !p.regime.is_temporal()).count();
    let n_temporal = result.plans.iter().filter(|p| p.regime.is_temporal()).count();
    assert!(n_spatial > 0, "vgg16+alexnet@16b must admit spatial splits on zc706");
    assert!(n_temporal > 0, "vgg16+alexnet@16b must admit temporal schedules on zc706");

    // Non-domination under the merged (fps ↑, worst-case latency ↓)
    // objective: no frontier member is dominated by ANY plan — in
    // particular, no surviving spatial plan is beaten by a temporal plan
    // on both axes, and vice versa.
    for &i in &result.frontier {
        for (j, p) in result.plans.iter().enumerate() {
            assert!(
                j == i || !plan_dominates(p, &result.plans[i]),
                "frontier member {i} ({}) dominated by plan {j} ({})",
                result.plans[i].regime.label(),
                p.regime.label()
            );
        }
    }
    // Completeness: every excluded plan is dominated by someone.
    for (i, p) in result.plans.iter().enumerate() {
        if !result.frontier.contains(&i) {
            assert!(
                result
                    .plans
                    .iter()
                    .enumerate()
                    .any(|(j, q)| j != i && plan_dominates(q, p)),
                "plan {i} ({}) excluded from the frontier but undominated",
                p.regime.label()
            );
        }
    }

    // Every plan serves both tenants, with both objective axes populated.
    for p in &result.plans {
        assert_eq!(p.fps.len(), 2);
        assert!(p.fps.iter().all(|&f| f > 0.0 && f.is_finite()));
        assert_eq!(p.latency_s.len(), 2);
        assert!(p.latency_s.iter().all(|&l| l > 0.0 && l.is_finite()));
    }
}

#[test]
fn timeshared_des_confirms_analytic_schedule_within_one_percent() {
    // Acceptance criterion: the chosen temporal plans' per-tenant fps is
    // reproduced by one executed schedule period (drain → reconfigure →
    // refill, dead cycles charged) within 1% of the analytic schedule.
    let sharder = Sharder {
        schedule: ScheduleMode::Temporal,
        ..auto_sharder()
    };
    let result = sharder.search().unwrap();
    assert!(!result.frontier.is_empty());
    let mut validated = 0;
    for &i in &result.frontier {
        let plan = &result.plans[i];
        let Regime::Temporal(info) = &plan.regime else {
            panic!("temporal-only search produced a spatial plan")
        };
        assert!(info.period_cycles > 0, "two tenants never degenerate to solo");
        let sims = plan.sim.as_ref().expect("sim_frames > 0 validates the frontier");
        assert_eq!(sims.len(), plan.fps.len());
        for (t, s) in sims.iter().enumerate() {
            let rel = (s.fps - plan.fps[t]).abs() / plan.fps[t];
            assert!(
                rel <= 0.01,
                "plan {i} tenant {t}: simulated {} vs analytic {} fps ({:.3}% off)",
                s.fps,
                plan.fps[t],
                rel * 100.0
            );
        }
        validated += 1;
    }
    assert!(validated > 0);
}
