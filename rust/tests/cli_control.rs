//! End-to-end operator control plane through the real binary:
//! `serve --plan … --listen 127.0.0.1:0` runs in the background, the
//! kernel-assigned port is parsed from the announced `listening on …`
//! stdout line, and the `ctl` subcommands are driven against it — the
//! wire apply report must match a direct in-process apply, zero
//! relative deadlines must be rejected as expired, replay must be
//! byte-deterministic, and `ctl shutdown` must drain to a clean exit.

use std::io::{BufRead, BufReader};
use std::path::{Path, PathBuf};
use std::process::{Child, Command, Output, Stdio};

use flexipipe::board::zedboard;
use flexipipe::coordinator::BatchPolicy;
use flexipipe::fault::FaultPlan;
use flexipipe::ingest::{ArrivalProcess, IngestPolicy, IngestService, TenantTrace, TraceSpec};
use flexipipe::model::zoo;
use flexipipe::plan::{DeploymentPlan, Planner, Workload};
use flexipipe::quant::QuantMode;
use flexipipe::util::json;

fn bin() -> &'static str {
    env!("CARGO_BIN_EXE_flexipipe")
}

fn tmp_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("flexipipe_cli_control").join(name);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// Two feasible plans for the same workload with different θ splits —
/// the same pair the plan-diff suite uses, here driven over the wire.
fn plan_pair() -> (DeploymentPlan, DeploymentPlan) {
    let set = Planner::on(zedboard())
        .steps(8)
        .plan(
            &Workload::new(QuantMode::W8A8)
                .tenant(zoo::tinycnn())
                .tenant(zoo::lenet()),
        )
        .unwrap();
    let a = set.plans[set.best].clone();
    let b = set
        .plans
        .iter()
        .find(|p| p.tenants[0].dsp_parts != a.tenants[0].dsp_parts)
        .expect("an 8-step spatial search holds more than one split")
        .clone();
    (a, b)
}

/// A live `serve --listen` process and the address it announced.
struct Server {
    child: Child,
    addr: String,
}

impl Drop for Server {
    fn drop(&mut self) {
        let _ = self.child.kill();
        let _ = self.child.wait();
    }
}

impl Server {
    /// Drain via `ctl shutdown`, require a clean process exit, and
    /// return the shutdown response body.
    fn stop(mut self) -> String {
        let body = ctl_ok(&self.addr, &["shutdown"]);
        let status = self.child.wait().unwrap();
        assert!(status.success(), "serve exited with {status}");
        body
    }
}

/// Spawn `serve --plan … --listen 127.0.0.1:0` and parse the announced
/// address from the first stdout line.
fn start_server(plan_path: &Path) -> Server {
    let mut child = Command::new(bin())
        .args(["serve", "--plan", plan_path.to_str().unwrap()])
        .args(["--listen", "127.0.0.1:0"])
        .stdout(Stdio::piped())
        .stderr(Stdio::null())
        .spawn()
        .unwrap();
    let stdout = child.stdout.take().unwrap();
    let mut line = String::new();
    BufReader::new(stdout).read_line(&mut line).unwrap();
    let addr = match line.trim().strip_prefix("listening on ") {
        Some(a) => a.to_string(),
        None => panic!("serve announced {line:?}, not a listening line"),
    };
    Server { child, addr }
}

/// Run `flexipipe ctl <args> --addr <addr>` and return the raw output.
fn ctl(addr: &str, args: &[&str]) -> Output {
    let mut cmd = Command::new(bin());
    cmd.arg("ctl").args(args).args(["--addr", addr]);
    cmd.output().unwrap()
}

/// `ctl` that must succeed; returns stdout (the JSON response body).
fn ctl_ok(addr: &str, args: &[&str]) -> String {
    let out = ctl(addr, args);
    assert!(
        out.status.success(),
        "ctl {args:?} failed:\nstdout: {}\nstderr: {}",
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr)
    );
    String::from_utf8_lossy(&out.stdout).into_owned()
}

#[test]
fn control_plane_serves_polls_and_expires_deadlines_end_to_end() {
    let dir = tmp_dir("end_to_end");
    let (a, _) = plan_pair();
    let plan_path = dir.join("live.json");
    a.save(&plan_path).unwrap();
    let server = start_server(&plan_path);
    let addr = server.addr.clone();

    // Introspection: both tenants show up healthy with empty queues.
    let health = json::parse(ctl_ok(&addr, &["health"]).trim()).unwrap();
    assert_eq!(health.req("tenants").unwrap().as_arr().unwrap().len(), 2);
    let queues = json::parse(ctl_ok(&addr, &["queues"]).trim()).unwrap();
    let qs = queues.req("queues").unwrap().as_arr().unwrap();
    assert_eq!(qs.len(), 2);
    assert_eq!(qs[0].str_field("tenant").unwrap(), "tinycnn");

    // GET /plan round-trips the served plan byte for byte.
    let live = ctl_ok(&addr, &["plan"]);
    assert_eq!(live.trim_end(), a.to_json().to_pretty());

    // Submit one frame and poll it to completion.
    let resp = ctl_ok(&addr, &["submit", "--tenant", "tinycnn"]);
    let v = json::parse(resp.trim()).unwrap();
    assert_eq!(v.str_field("state").unwrap(), "queued");
    let id = v.usize_field("id").unwrap().to_string();
    let mut last = String::new();
    for _ in 0..1000 {
        last = ctl_ok(&addr, &["poll", "--id", &id]);
        let state = json::parse(last.trim()).unwrap();
        match state.str_field("state").unwrap() {
            "done" => break,
            "failed" => panic!("request failed: {last}"),
            _ => std::thread::sleep(std::time::Duration::from_millis(5)),
        }
    }
    let done = json::parse(last.trim()).unwrap();
    assert_eq!(done.str_field("state").unwrap(), "done");
    assert!(done.usize_field("output_len").unwrap() > 0);
    // The result was consumed: a second poll is a 404, so ctl fails.
    assert!(!ctl(&addr, &["poll", "--id", &id]).status.success());

    // The acceptance property over the wire: a zero relative deadline
    // is dead on arrival — rejected 408/deadline-expired, never served.
    let dl = ctl(&addr, &["submit", "--tenant", "0", "--deadline", "0"]);
    assert!(!dl.status.success());
    let err = String::from_utf8_lossy(&dl.stderr).into_owned();
    assert!(err.contains("408"), "{err}");
    assert!(err.contains("deadline-expired"), "{err}");

    let final_body = server.stop();
    let v = json::parse(final_body.trim()).unwrap();
    assert_eq!(v.req("shut_down").unwrap().as_bool(), Some(true));
}

#[test]
fn ctl_apply_report_matches_the_direct_in_process_apply() {
    let dir = tmp_dir("apply");
    let (a, b) = plan_pair();
    let live_path = dir.join("live.json");
    let target_path = dir.join("target.json");
    a.save(&live_path).unwrap();
    b.save(&target_path).unwrap();

    // The oracle: the same diff applied to an in-process service.
    let diff = a.diff(&b).unwrap();
    let mut direct =
        IngestService::start(&a, BatchPolicy::default(), IngestPolicy::default()).unwrap();
    let direct_report = direct.apply(&diff).unwrap().to_json().to_pretty();
    let _ = direct.shutdown();

    let server = start_server(&live_path);
    let addr = server.addr.clone();
    let wire_report = ctl_ok(&addr, &["apply", target_path.to_str().unwrap()]);
    assert_eq!(
        wire_report.trim_end(),
        direct_report,
        "wire apply report diverged from the direct in-process apply"
    );
    // The live plan landed on the target bytes.
    let live = ctl_ok(&addr, &["plan"]);
    assert_eq!(live.trim_end(), b.to_json().to_pretty());
    server.stop();
}

#[test]
fn ctl_replay_is_deterministic_and_replan_keeps_tenants() {
    let dir = tmp_dir("replay_replan");
    let (a, _) = plan_pair();
    let plan_path = dir.join("live.json");
    a.save(&plan_path).unwrap();
    let spec = TraceSpec {
        seed: 7,
        duration_s: 1.0,
        queue_capacity: 0,
        tenants: vec![
            TenantTrace {
                tenant: "tinycnn".to_string(),
                process: ArrivalProcess::Poisson { rate_fps: 40.0 },
            },
            TenantTrace {
                tenant: "lenet".to_string(),
                process: ArrivalProcess::ClosedLoop {
                    clients: 2,
                    think_time_s: 0.05,
                },
            },
        ],
    };
    let trace_path = dir.join("trace.json");
    spec.save(&trace_path).unwrap();
    let faults_path = dir.join("faults.json");
    FaultPlan::none().save(&faults_path).unwrap();

    let server = start_server(&plan_path);
    let addr = server.addr.clone();
    let trace = trace_path.to_str().unwrap();

    // Replay is pure seeded arithmetic: two wire runs, identical bytes.
    let r1 = ctl_ok(&addr, &["replay", trace]);
    let r2 = ctl_ok(&addr, &["replay", trace]);
    assert_eq!(r1, r2, "wire replay must be byte-deterministic");
    let report = json::parse(r1.trim()).unwrap();
    assert_eq!(report.req("tenants").unwrap().as_arr().unwrap().len(), 2);

    // A no-fault replan keeps both tenants and applies cleanly.
    let out = ctl_ok(&addr, &["replan", faults_path.to_str().unwrap()]);
    let v = json::parse(out.trim()).unwrap();
    assert_eq!(v.req("replanned").unwrap().as_bool(), Some(true));
    assert!(v.req("shed").unwrap().as_arr().unwrap().is_empty());
    server.stop();
}
