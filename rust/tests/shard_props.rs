//! Property + regression tests for the multi-tenant sharder (spatial *and*
//! temporal regimes) and the shared-DDR multi-pipeline DES.

use flexipipe::alloc::flex::FlexAllocator;
use flexipipe::alloc::Allocator;
use flexipipe::board::{zc706, zedboard, Board};
use flexipipe::model::{conv, zoo, Network};
use flexipipe::quant::QuantMode;
use flexipipe::shard::{
    plan_dominates, sub_board, Regime, ReconfigModel, ScheduleMode, Sharder, Tenant,
};
use flexipipe::sim;
use flexipipe::util::prop::{check, Rng};

fn random_board(rng: &mut Rng) -> Board {
    let mut b = zc706();
    b.dsps = rng.urange(128, 1600);
    b.bram36 = rng.urange(200, 900);
    b.ddr_bytes_per_sec = rng.urange(3, 16) as f64 * 1e9;
    b
}

fn small_tenant(rng: &mut Rng) -> Tenant {
    let net = match rng.urange(0, 2) {
        0 => zoo::tinycnn(),
        1 => zoo::lenet(),
        _ => zoo::vgg_micro(),
    };
    let mode = *rng.pick(&[QuantMode::W8A8, QuantMode::W16A16]);
    Tenant::new(net, mode)
}

#[test]
fn prop_every_plan_is_feasible() {
    // Per-tenant DSP/BRAM use within each slice, and slice sums within the
    // physical board — no plan may oversubscribe anything.
    check("shard-feasible", 12, |rng| {
        let board = random_board(rng);
        let n = rng.urange(2, 3);
        let tenants: Vec<Tenant> = (0..n).map(|_| small_tenant(rng)).collect();
        let sharder = Sharder {
            steps: rng.urange(4, 8),
            ..Sharder::new(board.clone(), tenants)
        };
        let Ok(result) = sharder.search() else {
            return; // board too small for this tenant set: nothing to check
        };
        for plan in &result.plans {
            let mut dsp_parts = 0;
            let mut bram_parts = 0;
            for t in &plan.tenants {
                let sub = sub_board(&board, t.dsp_parts, t.bram_parts, sharder.steps);
                assert!(
                    t.report.dsps <= sub.dsps,
                    "tenant over its DSP slice: {} > {}",
                    t.report.dsps,
                    sub.dsps
                );
                assert!(
                    t.report.bram18 <= sub.bram18(),
                    "tenant over its BRAM slice: {} > {}",
                    t.report.bram18,
                    sub.bram18()
                );
                dsp_parts += t.dsp_parts;
                bram_parts += t.bram_parts;
            }
            assert_eq!(dsp_parts, sharder.steps, "Θ quanta must partition");
            assert_eq!(bram_parts, sharder.steps, "α quanta must partition");
            let dsps: usize = plan.tenants.iter().map(|t| t.report.dsps).sum();
            let bram: usize = plan.tenants.iter().map(|t| t.report.bram18).sum();
            assert!(dsps <= board.dsps, "board DSPs oversubscribed");
            assert!(bram <= board.bram18(), "board BRAM oversubscribed");
        }
    });
}

#[test]
fn prop_frontier_is_nondominated_and_complete() {
    check("shard-frontier", 8, |rng| {
        let board = random_board(rng);
        let tenants = vec![small_tenant(rng), small_tenant(rng)];
        let sharder = Sharder {
            steps: 6,
            ..Sharder::new(board, tenants)
        };
        let Ok(result) = sharder.search() else { return };
        // No frontier member is dominated — under the merged
        // (fps ↑, worst-case latency ↓) objective — by any plan.
        for &i in &result.frontier {
            for (j, p) in result.plans.iter().enumerate() {
                assert!(
                    j == i || !plan_dominates(p, &result.plans[i]),
                    "frontier member {i} dominated by plan {j}"
                );
            }
        }
        // Every non-frontier plan is dominated by someone.
        for (i, p) in result.plans.iter().enumerate() {
            if !result.frontier.contains(&i) {
                assert!(
                    result
                        .plans
                        .iter()
                        .enumerate()
                        .any(|(j, q)| j != i && plan_dominates(q, p)),
                    "plan {i} excluded from the frontier but undominated"
                );
            }
        }
        // The scalarized picks are consistent with the plan set.
        let best_min = &result.plans[result.best_min];
        assert!(result
            .plans
            .iter()
            .all(|p| p.min_fps <= best_min.min_fps));
        let best_w = &result.plans[result.best_weighted];
        assert!(result
            .plans
            .iter()
            .all(|p| p.weighted_fps <= best_w.weighted_fps));
    });
}

#[test]
fn single_tenant_shard_is_bit_identical_to_flex_allocator() {
    for (net, mode) in [
        (zoo::tinycnn(), QuantMode::W8A8),
        (zoo::lenet(), QuantMode::W16A16),
        (zoo::zf(), QuantMode::W16A16),
        (zoo::vgg16(), QuantMode::W8A8),
    ] {
        let sharder = Sharder::new(zc706(), vec![Tenant::new(net.clone(), mode)]);
        let result = sharder.search().unwrap();
        assert_eq!(result.plans.len(), 1, "{}: one split only", net.name);
        assert_eq!(result.frontier, vec![0]);
        let shard_alloc = &result.plans[0].tenants[0].alloc;
        assert_eq!(shard_alloc.board, zc706(), "{}: sub-board must be the board", net.name);

        let plain = FlexAllocator::default().allocate(&net, &zc706(), mode).unwrap();
        for (a, b) in shard_alloc.stages.iter().zip(&plain.stages) {
            assert_eq!(a.cfg, b.cfg, "{}: stage configs diverge", net.name);
        }
        let (rs, rp) = (shard_alloc.evaluate(), plain.evaluate());
        assert_eq!(rs.t_frame_cycles, rp.t_frame_cycles, "{}", net.name);
        assert_eq!(rs.fps.to_bits(), rp.fps.to_bits(), "{}", net.name);
        assert_eq!(rs.bram18, rp.bram18, "{}", net.name);
        assert_eq!(
            result.plans[0].fps[0].to_bits(),
            rp.fps.to_bits(),
            "{}: reported fps diverges",
            net.name
        );
    }
}

/// A board with every partitionable resource doubled (and the same clock).
fn doubled(b: &Board) -> Board {
    Board {
        name: format!("{}x2", b.name),
        dsps: b.dsps * 2,
        luts: b.luts * 2,
        ffs: b.ffs * 2,
        bram36: b.bram36 * 2,
        ddr_bytes_per_sec: b.ddr_bytes_per_sec * 2.0,
        freq_hz: b.freq_hz,
    }
}

#[test]
fn two_identical_tenants_on_doubled_board_match_solo_cycles() {
    // The multi-pipeline DES regression anchor: each of two identical
    // tenants holding half of a doubled board gets a WFQ share of the
    // doubled port that works out to exactly the original board's
    // bandwidth, so both schedules must be *bit-identical* to the solo run
    // — any cross-tenant interference in the model would break this.
    for (net, frames) in [(zoo::tinycnn(), 4), (zoo::lenet(), 3), (zoo::vgg_micro(), 3)] {
        for base in [zc706(), zedboard()] {
            let solo = FlexAllocator::default()
                .allocate(&net, &base, QuantMode::W8A8)
                .unwrap();
            let solo_sim = sim::simulate(&solo, frames);

            let big = doubled(&base);
            // Half of the doubled board is exactly the original board.
            let half = sub_board(&big, 1, 1, 2);
            assert_eq!(half.dsps, base.dsps);
            assert_eq!(half.bram36, base.bram36);
            assert_eq!(half.ddr_bytes_per_sec.to_bits(), base.ddr_bytes_per_sec.to_bits());
            let a = FlexAllocator::default()
                .allocate(&net, &half, QuantMode::W8A8)
                .unwrap();
            for (x, y) in a.stages.iter().zip(&solo.stages) {
                assert_eq!(x.cfg, y.cfg, "{}: half-of-doubled allocation differs", net.name);
            }

            // Both port models must agree here: equal tenants, equal
            // provisioned shares, equal demand.
            let prov = sim::engines::simulate_multi_provisioned(&[&a, &a], &[0.5, 0.5], &big, frames);
            let sims = sim::engines::simulate_multi(&[&a, &a], &big, frames);
            assert_eq!(sims.len(), 2);
            for (s, p) in sims.iter().zip(&prov) {
                assert_eq!(s.makespan, p.makespan, "{}: port models disagree", net.name);
                assert_eq!(
                    s.cycles_per_frame.to_bits(),
                    p.cycles_per_frame.to_bits(),
                    "{}",
                    net.name
                );
            }
            for (t, s) in sims.iter().enumerate() {
                assert_eq!(
                    s.makespan, solo_sim.makespan,
                    "{} tenant {t}: makespan diverges from solo",
                    net.name
                );
                assert_eq!(
                    s.cycles_per_frame.to_bits(),
                    solo_sim.cycles_per_frame.to_bits(),
                    "{} tenant {t}: beat diverges from solo",
                    net.name
                );
                assert_eq!(s.ddr_bytes, solo_sim.ddr_bytes, "{} tenant {t}", net.name);
                assert_eq!(s.stages, solo_sim.stages, "{} tenant {t}", net.name);
            }
        }
    }
}

#[test]
fn provisioned_shares_isolate_tenants_from_neighbors() {
    // The whole point of the provisioned port model: a tenant's schedule
    // depends only on its own share of β, never on who it shares the board
    // with — so swapping its neighbor must not move a cycle.
    let board = zc706();
    let half = sub_board(&board, 1, 1, 2);
    let a = FlexAllocator::default()
        .allocate(&zoo::tinycnn(), &half, QuantMode::W8A8)
        .unwrap();
    let light = FlexAllocator::default()
        .allocate(&zoo::lenet(), &half, QuantMode::W8A8)
        .unwrap();
    let heavy = FlexAllocator::default()
        .allocate(&zoo::vgg_micro(), &half, QuantMode::W8A8)
        .unwrap();
    let with_light = sim::engines::simulate_multi_provisioned(&[&a, &light], &[0.5, 0.5], &board, 3);
    let with_heavy = sim::engines::simulate_multi_provisioned(&[&a, &heavy], &[0.5, 0.5], &board, 3);
    assert_eq!(with_light[0].makespan, with_heavy[0].makespan);
    assert_eq!(
        with_light[0].cycles_per_frame.to_bits(),
        with_heavy[0].cycles_per_frame.to_bits()
    );
    // Solo with the full port at share 1.0 is the plain simulation.
    let solo = sim::engines::simulate_multi_provisioned(&[&a], &[1.0], &half, 3);
    let plain = sim::simulate(&a, 3);
    assert_eq!(solo[0].makespan, plain.makespan);
    assert_eq!(solo[0].stages, plain.stages);
}

// ---------------------------------------------------------------------------
// Temporal (time-multiplexed) scheduler properties
// ---------------------------------------------------------------------------

#[test]
fn prop_temporal_time_conservation() {
    // Slice fractions + reconfiguration dead time account for the whole
    // period: quanta partition `steps`, every feasible slice covers its
    // reconfiguration + pipeline refill, fps is exactly frames·f/period,
    // and the analytic dead fraction is the planner's own arithmetic.
    check("timeshare-conservation", 8, |rng| {
        let board = random_board(rng);
        let tenants = vec![small_tenant(rng), small_tenant(rng)];
        let sharder = Sharder {
            steps: rng.urange(2, 6),
            schedule: ScheduleMode::Temporal,
            max_period_s: 0.2,
            ..Sharder::new(board.clone(), tenants)
        };
        let Ok(result) = sharder.search() else {
            return; // temporal regime infeasible here: nothing to check
        };
        for plan in &result.plans {
            let Regime::Temporal(info) = &plan.regime else {
                panic!("temporal-only search produced a spatial plan")
            };
            assert_eq!(info.time_parts.iter().sum::<usize>(), sharder.steps);
            assert_eq!(info.period_cycles, info.quantum_cycles * sharder.steps as u64);
            // The sub-slice sequence partitions the period, and every
            // sub-slice covers its *charged* (drain-overlap-credited)
            // reconfiguration plus the pipeline refill.
            assert_eq!(
                info.slices.iter().map(|s| s.parts).sum::<usize>(),
                sharder.steps
            );
            for s in &info.slices {
                let slice = s.parts as u64 * info.quantum_cycles;
                assert!(s.frames >= 1, "every sub-slice admits ≥1 frame");
                assert!(s.overlap_cycles <= s.reconfig_cycles);
                assert!(
                    s.reconfig_cycles - s.overlap_cycles + info.fill_cycles[s.tenant]
                        <= slice,
                    "sub-slice must cover charged reconfiguration + refill"
                );
            }
            let mut useful = 0u64;
            for i in 0..2 {
                assert!(info.frames[i] >= 1, "feasible plans admit ≥1 frame");
                let from_slices: usize = info
                    .slices
                    .iter()
                    .filter(|s| s.tenant == i)
                    .map(|s| s.frames)
                    .sum();
                assert_eq!(from_slices, info.frames[i]);
                let want = info.frames[i] as f64 * board.freq_hz / info.period_cycles as f64;
                assert_eq!(plan.fps[i].to_bits(), want.to_bits());
                assert!(info.latency_cycles[i] > 0);
                useful += info.frames[i] as u64 * info.beat_cycles[i];
            }
            let want_dead =
                1.0 - useful.min(info.period_cycles) as f64 / info.period_cycles as f64;
            assert_eq!(info.dead_frac.to_bits(), want_dead.to_bits());
            assert!((0.0..1.0).contains(&info.dead_frac));
        }
    });
}

#[test]
fn single_tenant_timeshare_is_bit_identical_to_flex_allocator() {
    // A lone tenant never switches: the temporal schedule degenerates to
    // continuous solo operation at exactly the plain allocator's fps.
    for (net, mode) in [
        (zoo::tinycnn(), QuantMode::W8A8),
        (zoo::zf(), QuantMode::W16A16),
        (zoo::vgg16(), QuantMode::W8A8),
    ] {
        let sharder = Sharder {
            schedule: ScheduleMode::Temporal,
            ..Sharder::new(zc706(), vec![Tenant::new(net.clone(), mode)])
        };
        let result = sharder.search().unwrap();
        assert_eq!(result.plans.len(), 1, "{}", net.name);
        let plan = &result.plans[0];
        let Regime::Temporal(info) = &plan.regime else {
            panic!("{}: expected a temporal plan", net.name)
        };
        assert_eq!(info.period_cycles, 0, "{}: solo schedule is continuous", net.name);
        assert_eq!(info.reconfig_cycles, vec![0], "{}: no switches, no reconfig", net.name);
        let plain = FlexAllocator::default().allocate(&net, &zc706(), mode).unwrap();
        assert_eq!(
            plan.fps[0].to_bits(),
            plain.evaluate().fps.to_bits(),
            "{}: solo time-share must be the plain allocator, bit for bit",
            net.name
        );
    }
}

/// The 1-layer dominance board: full-budget Θ=225 decomposes 25×25 layers
/// with zero intra-group waste, so spatial slices can never beat their
/// proportional share — the regime where time multiplexing provably wins.
fn toy_board() -> Board {
    Board {
        name: "toy225".into(),
        dsps: 225,
        luts: 200_000,
        ffs: 400_000,
        bram36: 120,
        ddr_bytes_per_sec: 12.8e9,
        freq_hz: 200e6,
    }
}

fn one_layer_net(name: &str, hw: usize) -> Network {
    Network {
        name: name.into(),
        input: (25, hw, hw),
        layers: vec![conv(25, 25, hw, hw, 3, 1, 1)],
    }
}

#[test]
fn zero_reconfig_temporal_dominates_spatial_on_one_layer_nets() {
    // With free reconfiguration, giving each tenant the whole board in
    // turn wastes nothing, while a spatial slice of a 1-layer pipeline
    // decomposes strictly worse than proportionally (divisor staircase):
    // every spatial plan must be weakly dominated by some temporal plan.
    // (Margins are 15–25% on this configuration, far above the pipeline
    // fill amortization — verified against an independent numeric mirror.)
    for tenants in [
        vec![
            Tenant::new(one_layer_net("conv25a", 64), QuantMode::W16A16),
            Tenant::new(one_layer_net("conv25b", 64), QuantMode::W16A16),
        ],
        vec![
            Tenant::new(one_layer_net("conv25a", 64), QuantMode::W16A16),
            Tenant::new(one_layer_net("conv25c", 48), QuantMode::W16A16),
        ],
    ] {
        let sharder = Sharder {
            steps: 4,
            schedule: ScheduleMode::Auto,
            reconfig: ReconfigModel::zero(),
            max_period_s: 0.1,
            ..Sharder::new(toy_board(), tenants)
        };
        let result = sharder.search().unwrap();
        let temporal: Vec<&flexipipe::shard::ShardPlan> = result
            .plans
            .iter()
            .filter(|p| p.regime.is_temporal())
            .collect();
        assert!(!temporal.is_empty());
        let mut saw_spatial = false;
        for plan in result.plans.iter().filter(|p| !p.regime.is_temporal()) {
            saw_spatial = true;
            assert!(
                temporal.iter().any(|t| {
                    t.fps
                        .iter()
                        .zip(&plan.fps)
                        .all(|(ft, fs)| *ft >= fs * (1.0 - 1e-9))
                }),
                "spatial plan {:?} undominated by any temporal plan",
                plan.fps
            );
        }
        assert!(saw_spatial, "the toy board must admit spatial splits too");
        // Consequence: the egalitarian optimum is a temporal schedule.
        assert!(result.plans[result.best_min].regime.is_temporal());
    }
}

#[test]
fn two_identical_tenants_timeshare_half_solo_minus_reconfig() {
    // Acceptance anchor: two identical tenants time-sharing a ZC706 each
    // get half the solo fps minus the modeled reconfiguration + refill
    // overhead — and the reconfiguration-aware DES confirms the analytic
    // schedule within 1%.
    let mode = QuantMode::W16A16;
    let net = zoo::zf();
    let sharder = Sharder {
        steps: 2,
        schedule: ScheduleMode::Temporal,
        max_period_s: 0.4,
        calib_frames: 12,
        sim_frames: 1,
        ..Sharder::new(
            zc706(),
            vec![Tenant::new(net.clone(), mode), Tenant::new(net.clone(), mode)],
        )
    };
    let result = sharder.search().unwrap();
    let plan = &result.plans[result.best_min];
    let Regime::Temporal(info) = &plan.regime else {
        panic!("temporal-only search produced a spatial plan")
    };
    assert_eq!(info.time_parts, vec![1, 1], "identical tenants split time evenly");
    // Symmetric: bit-identical fps, frames, overheads.
    assert_eq!(plan.fps[0].to_bits(), plan.fps[1].to_bits());
    assert_eq!(info.frames[0], info.frames[1]);
    assert_eq!(info.reconfig_cycles[0], info.reconfig_cycles[1]);
    let freq = zc706().freq_hz;

    // Re-derive the schedule from public pieces: solo calibration via the
    // frame_done/input_done prefix properties + the reconfiguration model
    // + the drain-overlap credit (smallest drain in the planner's
    // 12-frame calibration window).
    let solo = FlexAllocator::default().allocate(&net, &zc706(), mode).unwrap();
    let cal = sim::simulate(&solo, 32);
    let rc = sharder.reconfig.cycles(&solo.evaluate(), freq);
    assert_eq!(info.reconfig_cycles[0], rc, "plan models the full reconfig cost");
    let drain_min = cal.frame_done[..12]
        .iter()
        .zip(&cal.input_done[..12])
        .map(|(&f, &i)| f - i)
        .min()
        .unwrap();
    let slice0 = info
        .slices
        .iter()
        .find(|s| s.tenant == 0)
        .expect("tenant 0 holds a sub-slice");
    assert_eq!(
        slice0.overlap_cycles,
        rc.min(drain_min),
        "the drain-overlap credit is the calibrated minimum drain"
    );
    let eff_rc = rc - slice0.overlap_cycles;
    let slice = info.time_parts[0] as u64 * info.quantum_cycles;
    let budget = slice.saturating_sub(eff_rc);
    let n = info.frames[0];
    assert!(n >= 1);
    // Admission is conservative and, inside the calibration window, exact:
    // never more frames than truly fit, at most one fewer.
    let n_true = cal.frame_done.iter().filter(|&&m| m <= budget).count();
    assert!(n <= n_true, "admitted {n} frames but only {n_true} fit");
    assert!(
        n + 2 >= n_true,
        "admission (n={n}) left more than a conservative margin vs the true fit {n_true}"
    );

    // "Half the solo fps minus the modeled overhead": bracket the analytic
    // fps by the calibrated beat. Upper: half the solo steady rate. Lower:
    // the provable admission bound (slice − reconfig − fill) / beat_max.
    let beat_max = cal
        .frame_done
        .windows(2)
        .map(|w| w[1] - w[0])
        .max()
        .unwrap() as f64;
    let fill = cal.frame_done[0] as f64;
    let half_solo = 0.5 * freq / beat_max;
    // (2% headroom absorbs beat variance between the planner's calibration
    // window and this test's longer one.)
    assert!(
        plan.fps[0] <= half_solo * 1.02,
        "time-share cannot beat half the solo rate ({} > {half_solo})",
        plan.fps[0]
    );
    let lower = ((slice as f64 - rc as f64 - fill) / beat_max).max(0.0) * freq
        / info.period_cycles as f64;
    assert!(
        plan.fps[0] >= lower - 1e-9,
        "deficit exceeds the modeled reconfig+refill overhead ({} < {lower})",
        plan.fps[0]
    );

    // The reconfiguration-aware DES confirms the analytic schedule.
    let sims = plan.sim.as_ref().expect("sim_frames > 0 validates the frontier");
    for (i, s) in sims.iter().enumerate() {
        let rel = (s.fps - plan.fps[i]).abs() / plan.fps[i];
        assert!(
            rel <= 0.01,
            "tenant {i}: DES fps {} vs analytic {} ({:.3}% off)",
            s.fps,
            plan.fps[i],
            rel * 100.0
        );
    }
}

#[test]
fn shard_search_validates_frontier_with_the_multi_des() {
    let sharder = Sharder {
        steps: 4,
        sim_frames: 2,
        ..Sharder::new(
            zedboard(),
            vec![
                Tenant::new(zoo::tinycnn(), QuantMode::W8A8),
                Tenant::new(zoo::lenet(), QuantMode::W8A8),
            ],
        )
    };
    let result = sharder.search().unwrap();
    for &i in &result.frontier {
        let sims = result.plans[i].sim.as_ref().expect("frontier plans get sim");
        assert_eq!(sims.len(), 2);
        for s in sims {
            assert!(s.fps > 0.0 && s.fps.is_finite());
            assert!(s.makespan > 0);
        }
    }
    // Non-frontier plans skip the (expensive) DES pass.
    for (i, p) in result.plans.iter().enumerate() {
        if !result.frontier.contains(&i) {
            assert!(p.sim.is_none());
        }
    }
}
